"""Closed-loop control plane: monitor, policy, loop, scenarios.

Covers the monitor's smoothing/streak bookkeeping, every policy decision
path (bootstrap, hold, cooldown, insurance rebalance, forced scale-down,
urgent bypass), the ControlLoop against node loss, the scalar-vs-vector
differential under controller-driven scaling, stepped-API/run()
equivalence for all three simulators, one smoke case per scenario, and
the check_bench diff engine.
"""
import importlib.util
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import Assignment, ElasticPlanner
from repro.data import task_state_sizes, task_workloads, node_count_trace
from repro.runtime import (
    AlwaysMigratePolicy, ChainedDataflowSim, ControlLoop, ElasticController,
    ElasticServingSim, MigrationPolicy, Monitor, NeverMigratePolicy,
    PolicyConfig, SCENARIOS, SimConfig, StageSpec, VectorizedServingSim,
    active_nodes, imbalance_ratio,
)
from repro.runtime.control import (
    Decision, forecast_mean_wait, pause_cost_tuple_s, select_strategy,
)
from repro.runtime.migration import Move
from repro.runtime.scenarios import make
from repro.runtime.state import BucketedState


def _metrics_matrix(mets):
    return np.array([[x.mean_response_s, x.max_response_s, x.delivered,
                      x.dropped_capacity, x.migration_duration_s,
                      x.forwarded, x.migration_cost_bytes,
                      x.restored_bytes, x.imbalance] for x in mets])


def _vec(m, tau=0.4, **kw):
    return VectorizedServingSim(m, SimConfig(slots_per_interval=20),
                                ElasticPlanner(policy="ssm_numpy"),
                                mode="live", tau=tau, **kw)


# ---------------------------------------------------------------------------
# Monitor
# ---------------------------------------------------------------------------

def test_monitor_ewma_and_streak():
    mon = Monitor(alpha=0.5, trigger=0.4)
    s1 = mon.observe(t=0, rate=10.0, backlog=0.0, imbalance=0.2)
    assert s1.imbalance_ewma == pytest.approx(0.2)
    assert s1.violation_streak == 0
    s2 = mon.observe(t=1, rate=10.0, backlog=5.0, imbalance=1.0)
    assert s2.imbalance_ewma == pytest.approx(0.6)
    assert s2.violation_streak == 1
    s3 = mon.observe(t=2, rate=10.0, backlog=9.0, imbalance=1.0)
    assert s3.imbalance_ewma == pytest.approx(0.8)
    assert s3.violation_streak == 2
    s4 = mon.observe(t=3, rate=10.0, backlog=0.0, imbalance=0.0)
    assert s4.violation_streak == 0          # streak resets on calm
    mon.reset()
    assert mon.observe(t=0, rate=1.0, backlog=0.0,
                       imbalance=0.9).violation_streak == 1


# ---------------------------------------------------------------------------
# Cost-model helpers
# ---------------------------------------------------------------------------

def test_forecast_mean_wait_overload_grows():
    # balanced, empty: just the service time
    base = forecast_mean_wait(np.array([5.0, 5.0]), np.zeros(2),
                              cap_node=10.0, horizon_s=100.0,
                              service_s=1e-3)
    assert base == pytest.approx(1e-3)
    # one overloaded node: wait grows with the horizon
    hot = forecast_mean_wait(np.array([15.0, 5.0]), np.zeros(2),
                             cap_node=10.0, horizon_s=100.0, service_s=1e-3)
    hotter = forecast_mean_wait(np.array([15.0, 5.0]), np.zeros(2),
                                cap_node=10.0, horizon_s=200.0,
                                service_s=1e-3)
    assert hot > base
    assert hotter > hot
    # backlog on a draining node raises the short-term wait only
    drain = forecast_mean_wait(np.array([5.0, 5.0]), np.array([100.0, 0.0]),
                               cap_node=10.0, horizon_s=100.0,
                               service_s=1e-3)
    assert drain > base


def test_pause_cost_matches_halved_window():
    w_rate = np.array([2.0, 0.0])
    un_from = np.array([0.0, 0.0])
    un_until = np.array([3.0, 0.0])
    # arrivals in a 3 s pause wait 1.5 s on average: 2/s * 3 s * 1.5 s
    assert pause_cost_tuple_s(w_rate, un_from, un_until, 0.0, 60.0) == \
        pytest.approx(9.0)
    # a full freeze charges every bucket
    assert pause_cost_tuple_s(np.array([1.0, 1.0]), np.zeros(2),
                              np.zeros(2), 4.0, 60.0) == pytest.approx(16.0)


def test_select_strategy_budget():
    small = [Move(0, 0, 1, 1_000.0)]
    mode, batch = select_strategy(small, bw_bytes_per_s=1e6,
                                  pause_budget_s=2.0)
    assert mode == "live"
    big = [Move(j, 0, 1, 0.5e6) for j in range(20)]  # 10 MB over 1 MB/s
    mode, batch = select_strategy(big, bw_bytes_per_s=1e6,
                                  pause_budget_s=2.0)
    # node 0 must send 20 buckets but only 4 fit per batch: multiple
    # rounds are unavoidable, so the batched scheduler wins
    assert mode == "batched_fluid"
    # batch · max-bucket transfer must fit in the pause budget
    assert batch * 0.5e6 / 1e6 <= 2.0 + 1e-9
    assert batch == 4
    # a single bucket above the budget can't be split: batch floors at 1
    huge = [Move(j, 0, 1, 5e6) for j in range(8)]
    assert select_strategy(huge, bw_bytes_per_s=1e6,
                           pause_budget_s=2.0) == ("batched_fluid", 1)
    # everything fits in one batch per node: plain fluid keeps the
    # simpler one-phase schedule
    spread = [Move(j, j, 10 + j, 0.5e6) for j in range(8)]
    assert select_strategy(spread, bw_bytes_per_s=1e6,
                           pause_budget_s=2.0) == ("fluid", 4)


# ---------------------------------------------------------------------------
# Policy decision paths
# ---------------------------------------------------------------------------

def _policy(m=16, tau=0.4, **cfg_kw):
    sv = _vec(m, tau=tau)
    cfg = PolicyConfig(tau_trigger=tau, tau_plan=tau / 2, **cfg_kw)
    return MigrationPolicy.for_sim(sv, cfg=cfg), sv


def test_policy_bootstrap_then_cooldown():
    pol, _ = _policy()
    assign = Assignment.from_boundaries(16, [0, 8, 16])
    d0 = pol.decide(None, assign, None, None, np.zeros(16), n_cap=2, t=0)
    assert d0.action == "rebalance" and d0.replan is True
    assert "bootstrap" in d0.reason
    # immediately after a migration the policy holds (cooldown); keep the
    # imbalance below the urgent bypass so the cooldown gate is what fires
    mon = Monitor(trigger=0.4)
    sig = mon.observe(t=0, rate=10.0, backlog=0.0, imbalance=0.5)
    w = np.ones(16)
    s = np.ones(16)
    d1 = pol.decide(sig, assign, w, s, np.zeros(16), n_cap=2, t=1)
    assert d1.action == "hold" and "cooldown" in d1.reason


def test_policy_holds_when_balanced():
    pol, _ = _policy()
    pol.last_migration_t = -100
    assign = Assignment.from_boundaries(16, [0, 8, 16])
    mon = Monitor(trigger=0.4)
    sig = mon.observe(t=5, rate=10.0, backlog=0.0, imbalance=0.1)
    d = pol.decide(sig, assign, np.ones(16), np.ones(16), np.zeros(16),
                   n_cap=4, t=6)
    assert d.action == "hold" and d.replan is False
    assert "balanced" in d.reason


def test_policy_rebalances_on_sustained_violation():
    pol, _ = _policy()
    pol.last_migration_t = -100
    # skewed loads under a uniform assignment: λ well above τ
    w = np.ones(16)
    w[:4] = 20.0
    assign = Assignment.from_boundaries(16, [0, 8, 16])
    assert imbalance_ratio(assign, w) > 0.4
    mon = Monitor(trigger=0.4)
    lam = imbalance_ratio(assign, w)
    sig = mon.observe(t=5, rate=float(w.sum()), backlog=50.0, imbalance=lam)
    d = pol.decide(sig, assign, w, np.ones(16) * 100.0, np.zeros(16),
                   n_cap=2, t=6)
    assert d.action == "rebalance" and d.replan is True
    assert d.mode in ("live", "fluid", "batched_fluid")


def test_policy_forced_scale_down_on_capacity_retraction():
    pol, _ = _policy()
    pol.last_migration_t = -100
    assign = Assignment.from_boundaries(16, [0, 4, 8, 12, 16])
    d = pol.decide(None, assign, np.ones(16), np.ones(16), np.zeros(16),
                   n_cap=2, t=3)
    assert d.action == "scale_down" and d.n_target == 2
    assert d.replan is True
    # forced moves restart the cooldown clock
    assert pol.last_migration_t == 3


def test_baseline_policies():
    assign = Assignment.from_boundaries(16, [0, 8, 16])
    always = AlwaysMigratePolicy()
    d = always.decide(None, assign, None, None, np.zeros(16), n_cap=5, t=0)
    assert d.n_target == 5 and d.replan is None     # legacy auto trigger
    never = NeverMigratePolicy()
    d = never.decide(None, assign, None, None, np.zeros(16), n_cap=5, t=0)
    assert d.action == "hold" and d.replan is False and d.n_target == 2


# ---------------------------------------------------------------------------
# ControlLoop end-to-end
# ---------------------------------------------------------------------------

def test_control_loop_node_loss_recovers():
    sc = make("node_loss", T=16, m=32)
    loop = ControlLoop(_vec(sc.m))
    rep = loop.run(sc)
    (t_fail, failed), = sc.failures.items()
    rec = [d for d in rep.decisions if d.action == "recover"]
    assert len(rec) == 1 and rec[0].t == t_fail
    assert rec[0].restored_bytes > 0          # checkpoint re-read
    assert rep.restored_bytes == rec[0].restored_bytes
    # the dead node is really gone
    assert rec[0].n_after == rec[0].n_before - len(failed)


def test_control_loop_is_repeatable():
    sc = make("diurnal", T=12, m=32)
    loop = ControlLoop(_vec(sc.m))
    a = _metrics_matrix(loop.run(sc).metrics)
    b = _metrics_matrix(loop.run(sc).metrics)   # same loop, fresh run
    np.testing.assert_array_equal(a, b)


def test_controller_differential_scalar_vs_vectorized():
    """Satellite: scalar and vectorized sims must agree at rtol 1e-9 when
    the *controller* (not a node trace) drives scaling."""
    sim = SimConfig(slots_per_interval=20)
    for name in ("diurnal", "skew_drift"):
        sc = make(name, T=12, m=32)
        scalar = ElasticServingSim(sc.m, sim,
                                   ElasticPlanner(policy="ssm_numpy"),
                                   mode="live", tau=0.4)
        vector = VectorizedServingSim(sc.m, sim,
                                      ElasticPlanner(policy="ssm_numpy"),
                                      mode="live", tau=0.4)
        rep_a = ControlLoop(scalar).run(sc)
        rep_b = ControlLoop(vector).run(sc)
        assert [d.action for d in rep_a.decisions] == \
            [d.action for d in rep_b.decisions]
        np.testing.assert_allclose(_metrics_matrix(rep_a.metrics),
                                   _metrics_matrix(rep_b.metrics),
                                   rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# Stepped API == run() for all three simulators
# ---------------------------------------------------------------------------

def _mk_trace(m, T, seed):
    w = task_workloads(m, T, seed=seed)
    s = task_state_sizes(w) * 2000.0
    return w, s, node_count_trace(w, 3, 6)


def test_scalar_step_equals_run():
    m, T = 24, 8
    w, s, trace = _mk_trace(m, T, seed=5)
    sim = SimConfig(slots_per_interval=20)
    ref = ElasticServingSim(m, sim, ElasticPlanner(policy="ssm_numpy"),
                            mode="fluid").run(w, s, trace)
    sv = ElasticServingSim(m, sim, ElasticPlanner(policy="ssm_numpy"),
                           mode="fluid")
    sv.reset(int(trace[0]))
    stepped = [sv.step_interval(w[t], s[t], int(trace[t]))
               for t in range(T)]
    np.testing.assert_array_equal(_metrics_matrix(ref),
                                  _metrics_matrix(stepped))


def test_vectorized_step_equals_run():
    m, T = 24, 8
    w, s, trace = _mk_trace(m, T, seed=6)
    ref = _vec(m).run(w, s, trace)
    sv = _vec(m)
    sv.reset(int(trace[0]))
    stepped = [sv.step_interval(w[t], s[t], int(trace[t]))
               for t in range(T)]
    np.testing.assert_array_equal(_metrics_matrix(ref),
                                  _metrics_matrix(stepped))


def test_chain_step_equals_run():
    m, T = 24, 6
    w, s, trace = _mk_trace(m, T, seed=7)
    sim = SimConfig(slots_per_interval=20)
    stages = [StageSpec("a", mode="live", tau=0.4,
                        planner=ElasticPlanner(policy="ssm_numpy")),
              StageSpec("b", mode="fluid", tau=0.6, state_scale=0.5,
                        planner=ElasticPlanner(policy="ssm_numpy"))]
    ref = ChainedDataflowSim(m, sim, stages).run(w, s, trace)
    stages2 = [StageSpec("a", mode="live", tau=0.4,
                         planner=ElasticPlanner(policy="ssm_numpy")),
               StageSpec("b", mode="fluid", tau=0.6, state_scale=0.5,
                         planner=ElasticPlanner(policy="ssm_numpy"))]
    chain = ChainedDataflowSim(m, sim, stages2)
    chain.reset(int(trace[0]))
    out = [[] for _ in stages2]
    for t in range(T):
        mets = chain.step_interval(w[t], s[t], int(trace[t]))
        for i, met in enumerate(mets):
            out[i].append(met)
    for i in range(len(stages2)):
        np.testing.assert_array_equal(_metrics_matrix(ref[i]),
                                      _metrics_matrix(out[i]))


# ---------------------------------------------------------------------------
# Scenario catalog
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_smoke(name):
    sc = make(name, T=10, m=24)
    assert sc.w.shape == (10, 24) and sc.s.shape == (10, 24)
    assert sc.capacity.shape == (10,)
    assert (sc.capacity >= 1).all()
    assert sc.total_state_bytes > 0
    rep = ControlLoop(_vec(sc.m)).run(sc)
    assert len(rep.metrics) == sc.T and len(rep.decisions) == sc.T
    assert all(d.signals for d in rep.decisions)
    # conservation: every interval's decision record carries real outcomes
    assert rep.bytes_moved >= 0 and rep.migrations <= sc.T


@pytest.mark.slow
def test_fig13_full_sweep():
    """Full benchmark incl. the policy-beats-baselines assertions."""
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    try:
        from benchmarks.fig13_controller import main
        main()
    finally:
        sys.path.pop(0)


# ---------------------------------------------------------------------------
# ElasticController emits decision records
# ---------------------------------------------------------------------------

def test_elastic_controller_decision_records():
    m = 16
    rng = np.random.default_rng(0)
    state = BucketedState([{"x": rng.random(4)} for _ in range(m)])
    ctl = ElasticController(m, 4, tau=0.6)
    w = np.ones(m)
    ctl.scale(5, w, state)
    w2 = np.ones(m)
    w2[:2] = 30.0
    ctl.maybe_rebalance(w2, state)
    ctl.recover({0}, w2, state)
    assert [d.action for d in ctl.decisions] == \
        ["scale", "rebalance", "recover"]
    # the legacy event log is a faithful view of the records
    assert [e.kind for e in ctl.events] == ["scale", "rebalance", "recover"]
    rec = ctl.decisions[-1]
    assert rec.restored_bytes > 0
    assert rec.signals["failed"] == [0]
    assert all(d.strategy == ctl.executor.mode for d in ctl.decisions)
    assert ctl.decisions[0].n_before == 4
    # SSM may leave the offered 5th node empty when τ already holds —
    # active nodes never drop below the starting count on a scale-up
    assert ctl.decisions[0].n_after >= 4


# ---------------------------------------------------------------------------
# check_bench diff engine
# ---------------------------------------------------------------------------

def _load_check_bench():
    path = Path(__file__).resolve().parents[1] / "scripts" / "check_bench.py"
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_bench_diff():
    cb = _load_check_bench()
    base = {"a": {"gain": 1.0, "elapsed_s": 5.0},
            "rows": [{"m": 64, "p99": 2.0}]}
    same = {"a": {"gain": 1.0 + 1e-9, "elapsed_s": 99.0},
            "rows": [{"m": 64, "p99": 2.0}]}
    assert cb.diff(base, same, rtol=1e-6) == []
    drift = {"a": {"gain": 1.5, "elapsed_s": 5.0},
             "rows": [{"m": 64, "p99": 2.0}]}
    assert any("gain" in e for e in cb.diff(base, drift, rtol=1e-6))
    shape = {"a": {"gain": 1.0, "elapsed_s": 5.0},
             "rows": [{"m": 64, "p99": 2.0}, {"m": 128, "p99": 1.0}]}
    assert any("length" in e for e in cb.diff(base, shape, rtol=1e-6))
    missing = {"rows": [{"m": 64, "p99": 2.0}]}
    assert any("missing" in e for e in cb.diff(base, missing, rtol=1e-6))
    # timing keys are exempt at any depth
    assert cb.is_timing_key("elapsed_s")
    assert cb.is_timing_key("first_s")
    assert cb.is_timing_key("ssm_plan_ms")
    assert not cb.is_timing_key("steady_p99_ms")
    assert not cb.is_timing_key("gain")
