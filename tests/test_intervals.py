"""Unit + property tests for interval/assignment primitives (paper §2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Assignment,
    balance_cap,
    migration_cost,
    migration_gain,
    moved_tasks,
    prefix_sum,
    satisfies_balance,
)
from repro.core.intervals import (
    count_balanced_partitions,
    enumerate_balanced_partitions,
    greedy_boundaries,
    match_gain,
    min_cover_counts,
    next_jump,
    realize_partition,
)


def rand_assignment(rng, m, n):
    cuts = np.sort(rng.choice(np.arange(1, m), size=n - 1, replace=False))
    return Assignment.from_boundaries(m, [0, *cuts.tolist(), m])


def test_prefix_and_measure():
    S = prefix_sum(np.array([1.0, 2.0, 3.0]))
    assert S.tolist() == [0, 1, 3, 6]


def test_assignment_validate_and_owner():
    a = Assignment.from_boundaries(10, [0, 4, 10])
    a.validate()
    assert a.owner_of().tolist() == [0] * 4 + [1] * 6
    with pytest.raises(ValueError):
        Assignment(10, ((0, 4), (5, 10))).validate()  # gap at 4


def test_gain_cost_complementary():
    rng = np.random.default_rng(0)
    for _ in range(50):
        m = int(rng.integers(4, 30))
        n1 = int(rng.integers(1, min(m, 6) + 1))
        n2 = int(rng.integers(1, min(m, 6) + 1))
        old = rand_assignment(rng, m, n1) if n1 > 1 else Assignment(m, ((0, m),))
        new = rand_assignment(rng, m, n2) if n2 > 1 else Assignment(m, ((0, m),))
        s = rng.uniform(0.1, 5.0, m)
        assert migration_gain(old, new, s) + migration_cost(old, new, s) == (
            pytest.approx(s.sum())
        )
        # cost == sum of state over tasks whose owner changed
        mask = moved_tasks(old, new)
        assert migration_cost(old, new, s) == pytest.approx(s[mask].sum())


def test_identity_migration_zero_cost():
    a = Assignment.from_boundaries(12, [0, 5, 9, 12])
    s = np.arange(1.0, 13.0)
    assert migration_cost(a, a, s) == 0.0


@given(
    m=st.integers(3, 16),
    seed=st.integers(0, 10_000),
    cap_mult=st.floats(1.05, 3.0),
)
@settings(max_examples=100, deadline=None)
def test_next_jump_and_cover(m, seed, cap_mult):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 2.0, m)
    cap = w.max() * cap_mult
    nxt = next_jump(w, cap)
    # every jump is maximal and feasible
    for a in range(m):
        b = int(nxt[a])
        assert w[a:b].sum() <= cap * (1 + 1e-9) + 1e-9
        if b < m:
            assert w[a : b + 1].sum() > cap
    cnt = min_cover_counts(nxt)
    bs = greedy_boundaries(nxt, 0, m)
    assert len(bs) - 1 == cnt[0]
    # greedy cover is minimal: any cover with fewer intervals is infeasible
    for k in range(1, int(cnt[0])):
        assert count_balanced_partitions(w, k, cap * k / w.sum() - 1) == 0


@given(m=st.integers(4, 12), k=st.integers(1, 5), seed=st.integers(0, 1000))
@settings(max_examples=60, deadline=None)
def test_enumerate_matches_count(m, k, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 2.0, m)
    tau = float(rng.uniform(0.0, 1.5))
    parts = list(enumerate_balanced_partitions(w, k, tau))
    assert len(parts) == count_balanced_partitions(w, k, tau)
    for p in parts:
        assert satisfies_balance(p, w, k, tau)
        assert len(p) == k + 1 and p[0] == 0 and p[-1] == m
        assert all(p[i] < p[i + 1] for i in range(k))


def brute_match(old_items, new_bounds, Ss):
    """Exhaustive max bipartite matching gain (crossing allowed).

    Recursion over new intervals; each is either unmatched or matched to an
    unused old node (injective both ways)."""
    k = len(new_bounds) - 1
    n = len(old_items)

    def ov(i, j):
        lo = max(old_items[i][1][0], new_bounds[j])
        hi = min(old_items[i][1][1], new_bounds[j + 1])
        return float(Ss[hi] - Ss[lo]) if hi > lo else 0.0

    def rec(j, used):
        if j == k:
            return 0.0
        best = rec(j + 1, used)  # leave new interval j unmatched
        for i in range(n):
            if not used & (1 << i):
                best = max(best, ov(i, j) + rec(j + 1, used | (1 << i)))
        return best

    return rec(0, 0)


@given(m=st.integers(3, 10), n=st.integers(1, 4), k=st.integers(1, 4),
       seed=st.integers(0, 5000))
@settings(max_examples=80, deadline=None)
def test_match_gain_equals_bruteforce(m, n, k, seed):
    """The non-crossing LCS DP equals unconstrained bipartite matching."""
    rng = np.random.default_rng(seed)
    n = min(n, m)
    k = min(k, m)
    old = rand_assignment(rng, m, n) if n > 1 else Assignment(m, ((0, m),))
    cuts = np.sort(rng.choice(np.arange(1, m), size=k - 1, replace=False))
    nb = [0, *cuts.tolist(), m]
    s = rng.uniform(0.1, 3.0, m)
    Ss = prefix_sum(s)
    g_dp, pairs = match_gain(old.nonempty(), nb, Ss)
    g_bf = brute_match(old.nonempty(), nb, Ss)
    assert g_dp == pytest.approx(g_bf)
    # matching is injective both ways
    assert len({p[0] for p in pairs}) == len(pairs)
    assert len({p[1] for p in pairs}) == len(pairs)


@given(m=st.integers(4, 12), n=st.integers(2, 4), k=st.integers(2, 4),
       seed=st.integers(0, 5000))
@settings(max_examples=60, deadline=None)
def test_realize_partition_achieves_match_gain(m, n, k, seed):
    rng = np.random.default_rng(seed)
    n, k = min(n, m - 1), min(k, m - 1)
    old = rand_assignment(rng, m, n)
    cuts = np.sort(rng.choice(np.arange(1, m), size=k - 1, replace=False))
    nb = [0, *cuts.tolist(), m]
    s = rng.uniform(0.1, 3.0, m)
    Ss = prefix_sum(s)
    g, _ = match_gain(old.nonempty(), nb, Ss)
    new = realize_partition(old, nb, s, k)
    new.validate()
    assert migration_gain(old, new, s) == pytest.approx(g)
