"""MTM-aware migration (paper §4) correctness tests: the MDP's up-to-k
partition space, value-iteration convergence, and the headline claim —
MTM total cost ≤ greedy single-step total cost over chain-sampled traces."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Assignment, MTM, PartitionTable, mtm_aware_plan, oms, pmc,
    satisfies_balance, ssm, greedy_sequence,
)


def chain_trace(probs, n_lo, start, length, seed):
    rng = np.random.default_rng(seed)
    trace = [start]
    ns = np.arange(n_lo, n_lo + probs.shape[0])
    for _ in range(length):
        trace.append(int(rng.choice(ns, p=probs[trace[-1] - n_lo])))
    return trace


def run_trace(policy, trace, m, w, s, tau, pmc_res=None):
    cuts = np.linspace(0, m, trace[0] + 1).round().astype(int)
    a = Assignment.from_boundaries(m, list(cuts))
    total = 0.0
    for n_new in trace[1:]:
        n_cur = sum(1 for lo, hi in a.intervals if hi > lo)
        if n_new == n_cur:
            continue
        plan = (ssm(a, n_new, w, s, tau) if policy == "ssm"
                else mtm_aware_plan(a, n_new, s, pmc_res))
        # every policy must satisfy the balance requirement
        assert satisfies_balance(plan.new, w, n_new, tau)
        total += plan.cost
        a = plan.new
    return total


def test_table_covers_up_to_k():
    """Partitions with j < k intervals must be feasible targets for k nodes
    when they fit the k-cap (the paper's 'up to n_max intervals')."""
    rng = np.random.default_rng(1)
    m = 10
    w = rng.uniform(0.5, 1.5, m)
    table = PartitionTable.build(w, 2, 5, tau=1.2)
    counts = np.asarray(table.n_counts)
    rows5 = table.feasible_rows(5)
    assert (counts[rows5] < 5).any(), "low-count rows must serve k=5"
    # every feasible row satisfies the k-cap
    from repro.core import balance_cap
    cap = balance_cap(w.sum(), 5, 1.2)
    assert (table.max_load[rows5] <= cap * (1 + 1e-9) + 1e-9).all()


def test_mtm_beats_greedy_on_chain_traces():
    rng = np.random.default_rng(0)
    m = 12
    w = rng.uniform(0.5, 2.0, m)
    s = rng.uniform(0.5, 2.0, m)
    tau = 0.8
    probs = np.array([[0.2, 0.5, 0.2, 0.1], [0.3, 0.2, 0.4, 0.1],
                      [0.1, 0.4, 0.2, 0.3], [0.1, 0.2, 0.5, 0.2]])
    mtm = MTM(3, 6, probs)
    table = PartitionTable.build(w, 3, 6, tau)
    res = pmc(table, s, mtm, gamma=0.9)
    wins = 0
    for seed in range(3):
        trace = chain_trace(probs, 3, 4, 150, seed)
        c_ssm = run_trace("ssm", trace, m, w, s, tau)
        c_mtm = run_trace("mtm", trace, m, w, s, tau, res)
        wins += c_mtm <= c_ssm * 1.02
    assert wins >= 2, "MTM should beat greedy on most chain traces"


def test_gamma_zero_matches_single_step_cost():
    """γ=0 reduces MTM to optimal single-step (Def. 2.8): per-migration cost
    equals SSM's optimum."""
    rng = np.random.default_rng(2)
    m = 10
    w = rng.uniform(0.5, 2.0, m)
    s = rng.uniform(0.5, 2.0, m)
    tau = 1.0
    mtm = MTM.uniform(2, 5)
    table = PartitionTable.build(w, 2, 5, tau)
    res = pmc(table, s, mtm, gamma=0.0)
    a = Assignment.from_boundaries(m, [0, 5, 10])
    for n_new in (3, 4, 5, 2):
        p_mtm = mtm_aware_plan(a, n_new, s, res)
        p_ssm = ssm(a, n_new, w, s, tau)
        assert p_mtm.cost == pytest.approx(p_ssm.cost, abs=1e-9)
        a = p_ssm.new


def test_pmc_values_monotone_in_gamma():
    rng = np.random.default_rng(3)
    m = 8
    w = rng.uniform(0.5, 1.5, m)
    s = rng.uniform(0.5, 1.5, m)
    mtm = MTM.uniform(2, 4)
    table = PartitionTable.build(w, 2, 4, tau=1.0)
    prev = None
    for gamma in (0.0, 0.5, 0.9):
        res = pmc(table, s, mtm, gamma=gamma)
        v = res.values.mean()
        if prev is not None:
            assert v >= prev - 1e-9   # longer horizon ⇒ larger values
        prev = v


def test_oms_not_worse_than_greedy_chain():
    rng = np.random.default_rng(4)
    m = 10
    w = np.ones(m)
    s = rng.uniform(0.5, 2.0, m)
    a = Assignment.from_boundaries(m, [0, 6, 10])
    targets = [(3, 0.6), (4, 0.6), (2, 0.6)]
    o = oms(a, targets, w, s)
    g = greedy_sequence(a, targets, w, s)
    assert o.total_cost <= g.total_cost + 1e-9
    # each step satisfies its balance constraint
    for plan, (n_i, tau_i) in zip(o.plans, targets):
        assert satisfies_balance(plan.new, w, n_i, tau_i)


def brute_sequence_cost(old, targets, w, s):
    """Exhaustive 2-step optimum: min over all (P1, P2) partition pairs of
    matching-cost(old→P1) + matching-cost(P1→P2)."""
    from repro.core.intervals import (
        enumerate_balanced_partitions, match_gain, prefix_sum,
    )
    from repro.core.oms import partition_items
    Ss = prefix_sum(s)
    total = float(Ss[-1])
    (n1, t1), (n2, t2) = targets
    best = np.inf
    p1s = list(enumerate_balanced_partitions(w, n1, t1))
    p2s = list(enumerate_balanced_partitions(w, n2, t2))
    for b1 in p1s:
        c1 = total - match_gain(old.nonempty(), list(b1), Ss)[0]
        for b2 in p2s:
            c2 = total - match_gain(partition_items(b1), list(b2), Ss)[0]
            if c1 + c2 < best:
                best = c1 + c2
    return best


@given(m=st.integers(5, 9), seed=st.integers(0, 300))
@settings(max_examples=15, deadline=None)
def test_oms_equals_bruteforce_two_step(m, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 1.5, m)
    s = rng.uniform(0.5, 2.0, m)
    cut = int(rng.integers(1, m))
    a = Assignment.from_boundaries(m, [0, cut, m])
    targets = [(3, 0.8), (2, 0.8)]
    try:
        o = oms(a, targets, w, s)
    except Exception:
        return
    bf = brute_sequence_cost(a, targets, w, s)
    assert o.total_cost == pytest.approx(bf, rel=1e-9, abs=1e-9)
