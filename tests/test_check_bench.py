"""check_bench gate semantics: a BENCH file absent at the baseline ref is
"new, pass with a notice" (no two-commit dance for benchmark-adding PRs),
while a broken git invocation — bad --ref in particular — is a hard error,
never a silent pass."""
import importlib.util
import json
import os
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

spec = importlib.util.spec_from_file_location(
    "check_bench", REPO / "scripts" / "check_bench.py")
check_bench = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_bench)


@pytest.fixture
def new_bench_file():
    path = REPO / "BENCH_unittest_tmp.json"
    path.write_text(json.dumps({"metric": 1.0}))
    try:
        yield path.name
    finally:
        os.unlink(path)


def test_new_file_passes_with_notice(new_bench_file, capsys):
    rc = check_bench.main([new_bench_file])
    out = capsys.readouterr().out
    assert rc == 0
    assert "NEW" in out and "passing" in out


def test_committed_returns_none_for_absent_path():
    assert check_bench.committed("BENCH_never_existed.json", "HEAD") is None


def test_committed_baseline_roundtrips():
    text = check_bench.committed("BENCH_ssm.json", "HEAD")
    assert text is not None
    json.loads(text)                     # parseable baseline


def test_bad_ref_is_a_hard_error(new_bench_file, capsys):
    rc = check_bench.main(["--ref", "no-such-ref-xyz", new_bench_file])
    out = capsys.readouterr().out
    assert rc == 2                       # not 0: the gate must not
    assert "does not name a commit" in out   # silently disable itself


def test_committed_raises_on_bad_ref():
    with pytest.raises(check_bench.GitError):
        check_bench.committed("BENCH_ssm.json", "no-such-ref-xyz")


def test_drift_still_fails(monkeypatch, capsys):
    """Numeric drift on a committed baseline still exits 1."""
    name = "BENCH_ssm.json"
    real = check_bench.committed
    base = json.loads(real(name, "HEAD"))

    def bump(node):
        """Perturb the first gated numeric leaf."""
        if isinstance(node, dict):
            for k, v in node.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool) \
                        and not check_bench.is_timing_key(k):
                    node[k] = v + 1.0
                    return True
                if bump(v):
                    return True
        elif isinstance(node, list):
            for v in node:
                if bump(v):
                    return True
        return False

    assert bump(base)
    fresh = REPO / "BENCH_unittest_drift.json"
    fresh.write_text(json.dumps(base))
    # serve the real baseline for the drifted copy's (uncommitted) name
    monkeypatch.setattr(check_bench, "committed",
                        lambda n, ref: real(name, ref))
    try:
        rc = check_bench.main([fresh.name])
    finally:
        os.unlink(fresh)
    out = capsys.readouterr().out
    assert rc == 1 and "FAIL" in out
