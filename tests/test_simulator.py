"""Vectorized simulator tests: differential equivalence against the legacy
scalar oracle, tuple-conservation invariants across every migration
strategy, the fluid-dominates-progressive latency property, and the
chained multi-operator engine."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ElasticPlanner
from repro.data import node_count_trace, task_state_sizes, task_workloads
from repro.runtime import (
    ChainedDataflowSim, ElasticServingSim, SimConfig, StageSpec,
    VectorizedServingSim, weighted_percentile,
)

MODES = ("kill_restart", "live", "progressive", "fluid", "batched_fluid")


def _metrics_matrix(mets):
    return np.array([[x.mean_response_s, x.max_response_s, x.delivered,
                      x.dropped_capacity, x.migration_duration_s,
                      x.forwarded, x.migration_cost_bytes] for x in mets])


def _mk_trace(m, T, seed, n_lo=4, n_hi=8, state_scale=2000.0):
    w = task_workloads(m, T, seed=seed)
    s = task_state_sizes(w) * state_scale
    trace = node_count_trace(w, n_lo, n_hi)
    return w, s, trace


# ---------------------------------------------------------------------------
# Differential: vectorized engine == scalar oracle, all strategies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_vectorized_matches_scalar_oracle(mode):
    m, T = 32, 20
    w, s, trace = _mk_trace(m, T, seed=5)
    sim = SimConfig()
    scalar = ElasticServingSim(m, sim, ElasticPlanner(policy="ssm"),
                               mode=mode)
    vector = VectorizedServingSim(m, sim, ElasticPlanner(policy="ssm"),
                                  mode=mode)
    a = _metrics_matrix(scalar.run(w, s, trace))
    b = _metrics_matrix(vector.run(w, s, trace))
    # identical delivered-tuple counts and per-interval latency profile
    np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-9)


@given(m=st.integers(8, 40), seed=st.integers(0, 500),
       n_lo=st.integers(2, 4), span=st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_vectorized_matches_scalar_oracle_property(m, seed, n_lo, span):
    w, s, trace = _mk_trace(m, 10, seed=seed, n_lo=n_lo, n_hi=n_lo + span)
    sim = SimConfig(slots_per_interval=20)
    for mode in ("live", "fluid", "batched_fluid"):
        a = _metrics_matrix(ElasticServingSim(
            m, sim, ElasticPlanner(policy="ssm"), mode=mode).run(w, s, trace))
        b = _metrics_matrix(VectorizedServingSim(
            m, sim, ElasticPlanner(policy="ssm"), mode=mode).run(w, s, trace))
        np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-9)


def test_jax_backend_matches_numpy():
    m, T = 32, 10
    w, s, trace = _mk_trace(m, T, seed=3)
    sim = SimConfig()
    a = VectorizedServingSim(m, sim, ElasticPlanner(policy="ssm"),
                             mode="fluid")
    b = VectorizedServingSim(m, sim, ElasticPlanner(policy="ssm"),
                             mode="fluid", backend="jax")
    ma = _metrics_matrix(a.run(w, s, trace))
    mb = _metrics_matrix(b.run(w, s, trace))
    # f32 accumulation on the jit path: loose tolerance
    np.testing.assert_allclose(ma, mb, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Conservation: no tuple lost or duplicated under any strategy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_tuple_conservation(mode):
    m, T = 24, 16
    w, s, trace = _mk_trace(m, T, seed=9)
    sv = VectorizedServingSim(m, SimConfig(), ElasticPlanner(policy="ssm"),
                              mode=mode)
    mets = sv.run(w, s, trace)
    delivered = sum(x.delivered for x in mets)
    backlog = mets[-1].dropped_capacity
    np.testing.assert_allclose(delivered + backlog, w.sum(), rtol=1e-9)
    # per-interval non-negativity
    assert all(x.delivered >= 0 for x in mets)
    assert all(x.dropped_capacity >= -1e-9 for x in mets)


# ---------------------------------------------------------------------------
# Fluid property: max latency spike <= progressive's on identical traces
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 200))
@settings(max_examples=8, deadline=None)
def test_fluid_spike_bounded_by_progressive(seed):
    m, T = 32, 12
    w = task_workloads(m, T, seed=seed, burst_prob=0.0, diurnal_amp=0.05,
                       zipf_a=0.5)
    s = task_state_sizes(w) * 3000.0
    trace = np.array([8] * (T // 2) + [6] * (T - T // 2))
    sim = SimConfig(interval_s=60.0)
    spikes = {}
    for mode in ("progressive", "fluid"):
        sv = VectorizedServingSim(m, sim, ElasticPlanner(policy="ssm"),
                                  mode=mode, tau=0.6)
        mets = sv.run(w, s, trace)
        spikes[mode] = max(x.max_response_s for x in mets)
    assert spikes["fluid"] <= spikes["progressive"] + 1e-9


def test_fluid_batch_interpolates_to_progressive():
    """fluid_batch=max_inflight with window-start 0 is progressive; a huge
    batch recovers live's single phase.  Here: larger batches must not
    shrink the worst spike below the batch=1 fluid run."""
    m, T = 32, 12
    w, s, trace = _mk_trace(m, T, seed=4, state_scale=3000.0)
    sim = SimConfig(interval_s=60.0)
    spikes = []
    for batch in (1, 4, 10_000):
        sv = VectorizedServingSim(m, sim, ElasticPlanner(policy="ssm"),
                                  mode="fluid", fluid_batch=batch, tau=0.6)
        mets = sv.run(w, s, trace)
        spikes.append(max(x.max_response_s for x in mets))
    assert spikes[0] <= spikes[1] + 1e-9
    assert spikes[0] <= spikes[2] + 1e-9


def test_weighted_percentile():
    v = np.array([1.0, 2.0, 3.0, 4.0])
    wt = np.array([1.0, 1.0, 1.0, 97.0])
    assert weighted_percentile(v, wt, 50) == 4.0
    assert weighted_percentile(v, wt, 1) == 1.0
    assert weighted_percentile(np.zeros(0), np.zeros(0), 99) == 0.0


def test_weighted_percentile_boundaries():
    v = np.array([1.0, 2.0, 3.0, 4.0])
    wt = np.array([1.0, 1.0, 1.0, 97.0])
    # q=100: cumulative target equals the total weight — float round-off
    # used to push searchsorted one past the end
    assert weighted_percentile(v, wt, 100) == 4.0
    assert weighted_percentile(v, np.ones(4), 100) == 4.0
    # q=0 skips zero-weight heads: the smallest value with any mass
    assert weighted_percentile(v, np.array([0.0, 5.0, 1.0, 1.0]), 0) == 2.0
    assert weighted_percentile(v, np.ones(4), 0) == 1.0
    # zero-weight tails never surface values beyond the carried mass
    assert weighted_percentile(v, np.array([1.0, 1.0, 0.0, 0.0]), 100) == 2.0
    # all-zero weights degrade to 0.0 rather than dividing by zero
    assert weighted_percentile(v, np.zeros(4), 99) == 0.0
    # irrational weights: q=100 must stay in bounds for any split
    rng = np.random.default_rng(0)
    for _ in range(20):
        vals = np.sort(rng.random(17))
        wts = rng.random(17) * np.pi
        assert weighted_percentile(vals, wts, 100) == vals[-1]
        assert weighted_percentile(vals, wts, 0) == vals[0]


# ---------------------------------------------------------------------------
# Chained multi-operator dataflow
# ---------------------------------------------------------------------------

def test_chain_single_stage_equals_solo_engine():
    m, T = 32, 10
    w, s, trace = _mk_trace(m, T, seed=11)
    sim = SimConfig()
    chain = ChainedDataflowSim(m, sim, [
        StageSpec("solo", mode="fluid", tau=0.4,
                  planner=ElasticPlanner(policy="ssm"))])
    per_stage = chain.run(w, s, trace)
    solo = VectorizedServingSim(m, sim, ElasticPlanner(policy="ssm"),
                                mode="fluid", tau=0.4)
    mets = solo.run(w, s, trace)
    a = _metrics_matrix(per_stage[0])
    b = _metrics_matrix(mets)
    np.testing.assert_allclose(a[:, :4], b[:, :4], rtol=1e-9, atol=1e-9)


def test_chain_conserves_tuples_across_stages():
    m, T = 32, 12
    w, s, trace = _mk_trace(m, T, seed=2)
    sim = SimConfig()
    chain = ChainedDataflowSim(m, sim, [
        StageSpec("map", mode="live"),
        StageSpec("aggregate", mode="fluid", route_seed=3),
        StageSpec("join", mode="progressive", route_seed=7,
                  state_scale=2.0),
    ])
    per_stage = chain.run(w, s, trace)
    # stage 0 consumes the external stream
    d0 = sum(x.delivered for x in per_stage[0])
    np.testing.assert_allclose(d0 + chain.final_queues[0].sum(), w.sum(),
                               rtol=1e-9)
    # each downstream stage consumes exactly what upstream delivered
    for i in (1, 2):
        di = sum(x.delivered for x in per_stage[i])
        up = sum(x.delivered for x in per_stage[i - 1])
        np.testing.assert_allclose(
            di + chain.final_queues[i].sum() + chain.final_inflow[i].sum(),
            up, rtol=1e-9)


def test_chain_migrations_overlap_across_stages():
    """Stages migrate independently: a node-count change hits every stage in
    the same interval, and each stage's windows are its own."""
    m, T = 24, 8
    w, s, trace = _mk_trace(m, T, seed=6, state_scale=3000.0)
    trace = np.array([6] * 4 + [4] * 4)
    chain = ChainedDataflowSim(m, SimConfig(interval_s=60.0), [
        StageSpec("a", mode="fluid"),
        StageSpec("b", mode="progressive", route_seed=5),
    ])
    per_stage = chain.run(w, s, trace)
    costs = [[x.migration_cost_bytes for x in stage] for stage in per_stage]
    # both stages migrated at t=4, concurrently
    assert costs[0][4] > 0 and costs[1][4] > 0
    e2e = chain.end_to_end_latency(per_stage)
    assert e2e.shape == (T,) and (e2e > 0).all()
