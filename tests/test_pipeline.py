"""GPipe pipeline tests: schedule correctness (pipeline == sequential),
transformer-stack equivalence, and the roll→collective-permute lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.pipeline import (
    pipeline_apply, pipeline_transformer_blocks, stack_stages,
)

KEY = jax.random.PRNGKey(0)


def test_pipeline_equals_sequential_toy():
    """4-stage matmul pipeline == applying the 4 matmuls in order."""
    S, n_micro, mb, d = 4, 6, 3, 8
    ws = jax.random.normal(KEY, (S, d, d)) / jnp.sqrt(d)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (n_micro, mb, d))

    def stage_fn(w, y):
        return jnp.tanh(y @ w)

    out = pipeline_apply(ws, x, stage_fn)
    assert out.shape == x.shape
    want = x
    for s in range(S):
        want = jnp.tanh(want @ ws[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_stack_stages_shapes():
    p = {"w": jnp.zeros((8, 3, 5)), "b": jnp.zeros((8, 5))}
    s = stack_stages(p, 4)
    assert s["w"].shape == (4, 2, 3, 5)
    assert s["b"].shape == (4, 2, 5)
    with pytest.raises(AssertionError):
        stack_stages(p, 3)


def test_pipeline_transformer_matches_scan():
    """Pipelined block stack == the model's sequential _run_depth."""
    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.models.transformer import _run_depth

    cfg = get_smoke("olmo-1b")          # uniform ("attn",) pattern, 4 layers
    params = init_params(cfg, KEY)
    B, S = 4, 32
    x = jax.random.normal(jax.random.fold_in(KEY, 2),
                          (B, S, cfg.d_model), jnp.bfloat16)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    want = _run_depth(x, params, cfg, positions, "masked")
    got = pipeline_transformer_blocks(
        params["blocks"], x, cfg, positions, n_stages=2, n_micro=2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_pipeline_roll_lowers_to_collective_permute():
    """With the stage dim sharded over a mesh axis, the inter-stage roll
    becomes collective-permute traffic (checked in a subprocess with 4
    devices so this process keeps 1)."""
    import json
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.pipeline import pipeline_apply
from repro.roofline.hlo import analyze

mesh = jax.make_mesh((4,), ("stage",))
S, n_micro, mb, d = 4, 8, 2, 16
def stage_fn(w, y):
    return jnp.tanh(y @ w)
sh = lambda s: NamedSharding(mesh, s)
f = jax.jit(lambda ws, x: pipeline_apply(ws, x, stage_fn),
            in_shardings=(sh(P("stage", None, None)), sh(P())),
            out_shardings=sh(P()))
with mesh:
    comp = f.lower(jax.ShapeDtypeStruct((S, d, d), jnp.float32),
                   jax.ShapeDtypeStruct((n_micro, mb, d), jnp.float32)
                   ).compile()
c = analyze(comp.as_text(), 4)
print(json.dumps({"cp": c.collective_breakdown.get("collective-permute", 0),
                  "counts": c.collective_counts}))
"""
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-1500:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["cp"] > 0, f"no collective-permute emitted: {rec}"
