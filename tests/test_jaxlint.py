"""jaxlint fixtures: one positive and one negative snippet per rule, the
suppression mechanism, and the acceptance gate that ``src/repro`` itself
lints clean (the same check ``scripts/ci.sh fast`` runs)."""
from pathlib import Path
from textwrap import dedent

from repro.analysis import JAX_RULES, lint_file, lint_paths

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"


def rules_of(findings):
    return {f.rule for f in findings}


def lint(text, path="runtime/migration.py"):
    """Lint a snippet; default path activates every rule incl. the
    JAX005 planner/scheduler module filter."""
    return lint_file(path, text=dedent(text))


def test_rule_catalog_is_complete():
    assert sorted(JAX_RULES) == [f"JAX00{i}" for i in range(1, 7)]


# ---------------------------------------------------------------------------
# JAX001 — mixed uint64/Python-int arithmetic
# ---------------------------------------------------------------------------

def test_jax001_bare_big_literal_fires():
    findings = lint("""
        import numpy as np
        def h(x):
            return x * 0x9E3779B97F4A7C15
    """)
    assert rules_of(findings) == {"JAX001"}


def test_jax001_uint64_mixed_with_bare_int_fires():
    findings = lint("""
        import numpy as np
        def h(x):
            return np.uint64(x) + 12345
    """)
    assert rules_of(findings) == {"JAX001"}


def test_jax001_properly_wrapped_hash_is_clean():
    # the actual post-PR-1 route() idiom: every literal inside uint64(...)
    findings = lint("""
        import numpy as np
        def route(keys, m, seed=0):
            k = np.asarray(keys, dtype=np.uint64)
            s = np.uint64((seed * 0x9E3779B97F4A7C15 + 0xBF58476D1CE4E5B9)
                          % (1 << 64))
            x = (k + s) * np.uint64(0x94D049BB133111EB)
            x ^= x >> np.uint64(29)
            return (x % np.uint64(m)).astype(np.int64)
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# JAX002 — tracer concretization inside jit/scan
# ---------------------------------------------------------------------------

def test_jax002_item_in_jit_fires():
    findings = lint("""
        import jax
        @jax.jit
        def f(x):
            return x.item()
    """)
    assert rules_of(findings) == {"JAX002"}


def test_jax002_float_in_scan_body_fires():
    findings = lint("""
        from jax import lax
        def body(carry, x):
            return carry + float(x), x
        def run(xs):
            return lax.scan(body, 0.0, xs)
    """)
    assert rules_of(findings) == {"JAX002"}


def test_jax002_item_outside_tracing_is_clean():
    findings = lint("""
        def summarize(arr):
            return arr.max().item()
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# JAX003 — numpy inside traced closures
# ---------------------------------------------------------------------------

def test_jax003_np_call_in_jit_fires():
    findings = lint("""
        import numpy as np
        import jax
        @jax.jit
        def f(x):
            return np.dot(x, x)
    """)
    assert rules_of(findings) == {"JAX003"}


def test_jax003_jnp_in_jit_is_clean():
    findings = lint("""
        import jax
        import jax.numpy as jnp
        @jax.jit
        def f(x):
            return jnp.dot(x, x)
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# JAX004 — unscoped x64 mutation
# ---------------------------------------------------------------------------

def test_jax004_config_update_fires():
    findings = lint("""
        from jax import config
        config.update("jax_enable_x64", True)
    """)
    assert rules_of(findings) == {"JAX004"}


def test_jax004_other_config_keys_are_clean():
    findings = lint("""
        from jax import config
        config.update("jax_platform_name", "cpu")
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# JAX005 — nondeterminism in planner/scheduler modules
# ---------------------------------------------------------------------------

def test_jax005_wall_clock_in_scheduler_fires():
    findings = lint("""
        import time
        def schedule(moves):
            return time.time()
    """, path="core/ssm.py")
    assert rules_of(findings) == {"JAX005"}


def test_jax005_alias_import_is_tracked():
    findings = lint("""
        import time as _time
        def schedule(moves):
            return _time.perf_counter()
    """, path="runtime/migration.py")
    assert rules_of(findings) == {"JAX005"}


def test_jax005_unseeded_np_random_fires_seeded_is_clean():
    bad = lint("""
        import numpy as np
        def plan():
            return np.random.rand(4)
    """, path="core/planner.py")
    assert rules_of(bad) == {"JAX005"}
    good = lint("""
        import numpy as np
        def plan():
            rng = np.random.default_rng(0)
            return rng.random(4)
    """, path="core/planner.py")
    assert good == []


def test_jax005_only_applies_to_planner_modules():
    findings = lint("""
        import time
        def bench():
            return time.time()
    """, path="models/zoo.py")
    assert findings == []


# ---------------------------------------------------------------------------
# JAX006 — mutable defaults
# ---------------------------------------------------------------------------

def test_jax006_mutable_default_arg_fires():
    findings = lint("""
        def register(name, registry={}):
            registry[name] = True
            return registry
    """)
    assert rules_of(findings) == {"JAX006"}


def test_jax006_dataclass_field_literal_fires():
    findings = lint("""
        from dataclasses import dataclass
        @dataclass
        class Report:
            items: list = []
    """)
    assert rules_of(findings) == {"JAX006"}


def test_jax006_default_factory_is_clean():
    findings = lint("""
        from dataclasses import dataclass, field
        @dataclass
        class Report:
            items: list = field(default_factory=list)
        def register(name, registry=None):
            return registry or {}
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

def test_trailing_suppression_comment():
    findings = lint("""
        import time
        def schedule(moves):
            return time.time()   # jaxlint: disable=JAX005 — measured wall clock
    """, path="core/ssm.py")
    assert findings == []


def test_preceding_line_suppression_comment():
    findings = lint("""
        import time
        def schedule(moves):
            # jaxlint: disable=JAX005 — measured wall clock
            return time.time()
    """, path="core/ssm.py")
    assert findings == []


def test_suppression_is_rule_specific():
    findings = lint("""
        import time
        def schedule(moves):
            return time.time()   # jaxlint: disable=JAX001
    """, path="core/ssm.py")
    assert rules_of(findings) == {"JAX005"}


# ---------------------------------------------------------------------------
# The acceptance gate: our own source tree is clean
# ---------------------------------------------------------------------------

def test_src_repro_lints_clean():
    findings = lint_paths([SRC])
    assert findings == [], "\n".join(str(f) for f in findings)
