"""Real-state migration tests: the device-resident bucketed KV view, the
JaxBackend row transfers, the serve-loop bit-identity across a live elastic
resize, and the controller/checkpoint bugs the simulated state was hiding
(SpeedTracker never resized, restore losing pytree nesting, restore reading
files for resident buckets)."""
import numpy as np
import pytest

from repro.core import ElasticPlanner
from repro.runtime import (
    BucketedState, CheckpointManager, DeviceBucketedState,
    ElasticController, JaxBackend, MigrationExecutor, SpeedTracker,
    cache_batch_axes, route, verify_resharding,
)

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402


def mk_fake_cache(B, seed=0):
    """Synthetic decode-cache pytree with the real layout: stacked
    ``blocks``/``cross_k`` leaves carry the request axis at 1, ``tail``
    leaves at 0."""
    rng = np.random.default_rng(seed)
    return {
        "blocks": ({"attn": {"k": jnp.asarray(rng.normal(size=(3, B, 4, 2))),
                             "pos": jnp.asarray(
                                 rng.integers(0, 9, (3, B, 4)))}},),
        "tail": ({"h": jnp.asarray(rng.normal(size=(B, 5)))},),
        "cross_k": jnp.asarray(rng.normal(size=(2, B, 6))),
    }


def mk_device_state(B=12, m=8, nodes=2, seed=0):
    cache = mk_fake_cache(B, seed)
    req_bucket = route(np.arange(B) + 7, m)
    ctl = ElasticController(m, nodes, tau=0.2,
                            planner=ElasticPlanner(policy="ssm"),
                            executor=MigrationExecutor(backend=JaxBackend(),
                                                       mode="live"))
    state = DeviceBucketedState.from_cache(
        cache, req_bucket, ctl.assign.owner_of(), cap=B)
    return cache, req_bucket, ctl, state


# ---------------------------------------------------------------------------
# Satellite 1: SpeedTracker must follow the topology
# ---------------------------------------------------------------------------

def test_speed_tracker_resized_on_scale():
    """Regression: the controller's SpeedTracker was sized at construction
    and never resized, so per-node step times after a scale-out crashed (or
    silently mis-broadcast).  Scale 2 -> 4 -> 3 feeding step times at every
    topology."""
    m = 12
    state = BucketedState([{"x": np.zeros(16)} for _ in range(m)])
    w = np.ones(m)
    ctl = ElasticController(m, 2, tau=0.2)
    ctl.speeds.update([1.0, 2.0])
    assert ctl.speeds.ewma.tolist() == [1.0, 2.0]

    ctl.scale(4, w, state)
    n4 = len(ctl.assign.intervals)
    assert len(ctl.speeds.ewma) == n4 >= 4
    # survivors keep their EWMA, new slots start unobserved
    assert ctl.speeds.ewma[0] == 1.0 and ctl.speeds.ewma[1] == 2.0
    ctl.speeds.update(np.arange(1, n4 + 1, dtype=float))

    ctl.scale(3, w, state)
    n3 = len(ctl.assign.intervals)
    assert len(ctl.speeds.ewma) == n3
    alive = [i for i, (lo, hi) in enumerate(ctl.assign.intervals) if hi > lo]
    assert len(alive) == 3
    # a survivor's estimate is carried over, not reset
    assert any(ctl.speeds.ewma[i] > 0 for i in alive)
    ctl.speeds.update(np.ones(n3))          # correct length: accepted

    with pytest.raises(ValueError):
        ctl.speeds.update(np.ones(n3 + 2))  # stale length: loud, not silent


def test_speed_tracker_resize_direct():
    tr = SpeedTracker(2)
    tr.update([1.0, 3.0])
    tr.resize(4, keep=[0, 1])
    assert tr.ewma.tolist() == [1.0, 3.0, 0.0, 0.0]
    tr.resize(2, keep=[1])
    assert tr.ewma.tolist() == [0.0, 3.0]
    with pytest.raises(ValueError):
        tr.update([1.0, 2.0, 3.0])


# ---------------------------------------------------------------------------
# Satellites 2+3: checkpoint structure round-trip and resident-skip restore
# ---------------------------------------------------------------------------

def _nested_bucket(j):
    return {"kv": {"k": np.full((2, 3), j, np.float32),
                   "v": np.full((2, 3), -j, np.float32)},
            "meta": (np.arange(j + 1), [np.float64(j), np.float64(j + 1)])}


@pytest.mark.parametrize("async_", [False, True])
def test_checkpoint_nested_roundtrip(tmp_path, async_):
    """Regression: save flattened nested pytrees to ``a/b`` npz keys but
    restore returned the flat dict — nested state came back unusable."""
    m, n = 6, 2
    state = BucketedState([_nested_bucket(j) for j in range(m)])
    ctl = ElasticController(m, n)
    extra = {"opt": {"mu": np.ones(4)}, "step": np.int64(7)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state, ctl.assign, extra=extra, async_=async_)
    mgr.wait()

    w = np.ones(m)
    restored, assign, rep, extra2 = mgr.restore(3, n, w, tau=1.2)
    assert rep.files_read == m and rep.files_resident == 0
    for j in range(m):
        want, got = _nested_bucket(j), restored.buckets[j]
        assert isinstance(got, dict) and set(got) == {"kv", "meta"}
        np.testing.assert_array_equal(got["kv"]["k"], want["kv"]["k"])
        np.testing.assert_array_equal(got["kv"]["v"], want["kv"]["v"])
        assert isinstance(got["meta"], tuple) and len(got["meta"]) == 2
        np.testing.assert_array_equal(got["meta"][0], want["meta"][0])
        assert isinstance(got["meta"][1], list)
        np.testing.assert_array_equal(got["meta"][1], want["meta"][1])
    # extra restored from the stored structure, no proto needed
    assert set(extra2) == {"opt", "step"}
    np.testing.assert_array_equal(extra2["opt"]["mu"], extra["opt"]["mu"])
    assert int(extra2["step"]) == 7


def test_restore_skips_resident_bucket_files(tmp_path, monkeypatch):
    """Regression: restore opened every bucket_*.npz even for buckets whose
    owner didn't change — the 'resident' bytes in the report were never
    actually free.  With the surviving in-memory state passed in, resident
    buckets must come from memory and their files must never be opened."""
    m, n = 8, 2
    state = BucketedState([_nested_bucket(j) for j in range(m)])
    ctl = ElasticController(m, n)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, ctl.assign)

    opened = []
    orig_load = np.load

    def spy_load(path, *a, **k):
        opened.append(str(path))
        return orig_load(path, *a, **k)

    monkeypatch.setattr(np, "load", spy_load)
    w = np.ones(m)
    restored, assign, rep, _ = mgr.restore(
        1, n, w, tau=1.2, resident_state=state)
    assert rep.files_resident > 0
    assert rep.files_read == sum("bucket_" in p for p in opened)
    assert rep.files_read + rep.files_resident == m
    assert rep.bytes_resident > 0
    # resident buckets are the in-memory objects, not copies read back
    owner_old = ctl.assign.owner_of()
    owner_new = assign.padded(max(ctl.assign.n_nodes,
                                  assign.n_nodes)).owner_of()
    for j in range(m):
        if owner_new[j] == owner_old[j]:
            assert restored.buckets[j] is state.buckets[j]


# ---------------------------------------------------------------------------
# Tentpole: device-resident bucketed state + real resharding
# ---------------------------------------------------------------------------

def test_cache_batch_axes_rule():
    cache = mk_fake_cache(4)
    axes = cache_batch_axes(cache)
    assert axes["blocks"][0]["attn"]["k"] == 1
    assert axes["blocks"][0]["attn"]["pos"] == 1
    assert axes["cross_k"] == 1
    assert axes["tail"][0]["h"] == 0


def test_bucket_bytes_from_real_leaf_shapes():
    B = 12
    cache, req_bucket, _, state = mk_device_state(B=B)
    # per-request bytes from the actual leaves, independent of which axis
    # carries the request dim
    per_req = sum(np.asarray(x).nbytes / B
                  for x in jax.tree_util.tree_leaves(cache))
    counts = np.bincount(req_bucket, minlength=state.m)
    np.testing.assert_allclose(state.bucket_bytes(), counts * per_req)


def test_device_state_roundtrip_and_gather():
    B = 12
    cache, req_bucket, _, state = mk_device_state(B=B)
    # gather of all requests reassembles the original cache exactly
    back = state.gather(np.arange(B))
    for a, b in zip(jax.tree_util.tree_leaves(cache),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_real_resharding_moves_rows_and_preserves_content():
    B, m = 12, 8
    cache, req_bucket, ctl, state = mk_device_state(B=B, m=m)
    pre = state.to_host().buckets
    w = np.bincount(req_bucket, minlength=m).astype(float) + 1e-9
    plan, rep = ctl.scale(3, w, state)
    assert rep.moves > 0 and rep.bytes_moved > 0
    assert len(rep.phase_link_bytes) == rep.phases
    # rows landed on the plan's new owners
    owner = ctl.assign.owner_of()
    assert np.array_equal(owner[state.req_bucket], state.req_node)
    # contents bit-identical under the plan's permutation layout
    verify_resharding(plan, state, pre)
    # and the host view still reassembles the original cache
    back = state.gather(np.arange(B))
    for a, b in zip(jax.tree_util.tree_leaves(cache),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resharding_detects_mutation():
    B, m = 12, 8
    _, req_bucket, ctl, state = mk_device_state(B=B, m=m)
    pre = state.to_host().buckets
    w = np.bincount(req_bucket, minlength=m).astype(float) + 1e-9
    plan, _ = ctl.scale(3, w, state)
    # corrupt one request's live row: verification must catch it
    node, row = int(state.req_node[0]), int(state.req_row[0])
    leaf = state.shards[node]["tail"][0]["h"]
    state.shards[node]["tail"][0]["h"] = leaf.at[row, 0].add(1.0)
    with pytest.raises(AssertionError):
        verify_resharding(plan, state, pre)


# ---------------------------------------------------------------------------
# Tentpole: serve loop — decode bit-identical across a live resize
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_resize_bit_identical():
    from repro.launch.serve import run_serving
    kw = dict(arch="qwen2.5-3b", smoke=True, requests=8, prompt_len=8,
              gen=8, buckets=8, nodes=2, seed=0)
    base = run_serving(resize=None, **kw)
    res = run_serving(resize=(3, 3), **kw)
    assert res.resize is not None
    assert res.resize["bytes_moved"] > 0
    assert res.resize["routing_ok"] and res.resize["verified"]
    assert np.array_equal(base.tokens, res.tokens)


# ---------------------------------------------------------------------------
# Elastic cache specs: request axis over the elastic mesh axis
# ---------------------------------------------------------------------------

def test_elastic_cache_specs_axis_placement():
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.configs import get_smoke
    from repro.launch.shardings import elastic_cache_specs
    from repro.models import init_cache

    cfg = get_smoke("qwen2.5-3b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 8, 16))
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    specs = elastic_cache_specs(cfg, mesh, cache, axis="data")

    def check(path, spec):
        names = [str(getattr(p, "key", getattr(p, "name",
                                               getattr(p, "idx", p))))
                 for p in path]
        ax = 1 if names[0] in ("blocks", "cross_k", "cross_v") else 0
        assert isinstance(spec, P)
        assert spec[ax] == "data", (names, spec)
        for i, e in enumerate(spec):
            if i != ax:
                assert e is None, (names, spec)

    jax.tree_util.tree_map_with_path(
        check, specs, is_leaf=lambda s: isinstance(s, P))
