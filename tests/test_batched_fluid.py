"""batched_fluid scheduler properties: every round is a valid matching,
rounds cover exactly the plan's moves, per-bucket pauses are own-transfer
only, degeneracy to fluid at infinite bandwidth, executor integration, and
the control loop actually choosing the strategy on a stock scenario."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import assert_clean, check_schedule
from repro.core import Assignment, ElasticPlanner, ssm
from repro.runtime import (
    BucketedState, ControlLoop, MigrationExecutor, Move, SCENARIOS,
    SimBackend, SimConfig, VectorizedServingSim, bucket_windows,
    fluid_budget, hopcroft_karp, round_windows, schedule_phases,
    schedule_rounds,
)


def _random_moves(rng: np.random.Generator, n_moves: int, n_nodes: int):
    out = []
    for j in range(n_moves):
        src, dst = rng.choice(n_nodes, size=2, replace=False)
        out.append(Move(bucket=j, src=int(src), dst=int(dst),
                        nbytes=float(rng.integers(1, 10_000))))
    return out


# ---------------------------------------------------------------------------
# Matching validity + exact coverage + maximality
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 1000), n_moves=st.integers(1, 120),
       n_nodes=st.integers(2, 12), batch=st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_rounds_are_maximal_matchings_covering_moves(seed, n_moves,
                                                     n_nodes, batch):
    rng = np.random.default_rng(seed)
    moves = _random_moves(rng, n_moves, n_nodes)
    rounds = schedule_rounds(moves, batch=batch)

    # exact coverage (PLN001) + matching validity and maximality (PLN002):
    # the shared analysis.plancheck oracle, so this test and the runtime's
    # verify hook can never disagree about what "correct rounds" means
    assert_clean(check_schedule(moves, rounds, "batched_fluid"))

    # batch budget: a link ships at most `cap` bytes beyond its first
    # (always-allowed) bucket — executor knob, not part of the PLN catalog
    cap = batch * max(mv.nbytes for mv in moves)
    for rnd in rounds:
        per_link = {}
        for mv in rnd:
            per_link.setdefault((mv.src, mv.dst), []).append(mv.nbytes)
        for sizes in per_link.values():
            assert sum(sizes[1:]) <= cap + 1e-9


@given(seed=st.integers(0, 300), n=st.integers(1, 10))
@settings(max_examples=20, deadline=None)
def test_hopcroft_karp_is_a_matching(seed, n):
    rng = np.random.default_rng(seed)
    adj = {int(u): sorted({int(v) for v in rng.choice(n, size=n // 2 + 1)})
           for u in rng.choice(n * 2, size=n, replace=False)}
    match = hopcroft_karp(adj)
    assert len(set(match.values())) == len(match)       # injective
    for u, v in match.items():
        assert v in adj[u]                              # only real edges


# ---------------------------------------------------------------------------
# Window semantics
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 500), batch=st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_pause_is_own_transfer_only(seed, batch):
    rng = np.random.default_rng(seed)
    moves = _random_moves(rng, 60, 6)
    bw = 1e4
    rounds = schedule_rounds(moves, batch=batch)
    un_from, un_until, clock = round_windows(rounds, bw, m=60)
    for mv in moves:
        assert un_until[mv.bucket] - un_from[mv.bucket] == \
            pytest.approx(mv.nbytes / bw)
    # the migration clock covers every window and is at least the busiest
    # endpoint's serial transfer (the bandwidth lower bound)
    assert clock >= un_until.max() - 1e-9
    # full-duplex lower bound: a node may send and receive concurrently,
    # but each direction is serial across rounds
    sends, recvs = {}, {}
    for mv in moves:
        sends[mv.src] = sends.get(mv.src, 0.0) + mv.nbytes
        recvs[mv.dst] = recvs.get(mv.dst, 0.0) + mv.nbytes
    lb = max(max(sends.values()), max(recvs.values())) / bw
    assert clock >= lb - 1e-9


def test_infinite_bandwidth_degenerates_to_fluid():
    """With bw → ∞ every transfer is instantaneous: batch=1 batched_fluid
    and batch=1 fluid produce identical (all-zero) windows and clocks."""
    rng = np.random.default_rng(7)
    moves = _random_moves(rng, 40, 5)
    bw = float("inf")
    sizes = np.zeros(40)
    for mv in moves:
        sizes[mv.bucket] = mv.nbytes
    phases = schedule_phases(moves, fluid_budget(sizes, 1))
    f_from, f_until, f_clock = bucket_windows(phases, bw, 40, fluid=True)
    rounds = schedule_rounds(moves, batch=1)
    r_from, r_until, r_clock = round_windows(rounds, bw, 40)
    np.testing.assert_allclose(f_from, r_from)
    np.testing.assert_allclose(f_until, r_until)
    assert f_clock == r_clock == 0.0


def test_sync_amortization_beats_fluid_on_scale_in():
    """The headline fig12 property at unit scale, on the topology elastic
    events actually produce (a few drained senders fanning out to many
    receivers, many buckets per link): with a per-round coordination
    barrier, 8-bucket batched rounds finish the migration strictly sooner
    than single-bucket fluid phases, at a per-bucket pause that is no
    worse."""
    rng = np.random.default_rng(11)
    moves, b = [], 0
    for src in (0, 1):                   # two nodes being drained
        for dst in (2, 3, 4, 5):
            for _ in range(20):
                moves.append(Move(bucket=b, src=src, dst=dst,
                                  nbytes=float(rng.integers(5_000, 15_000))))
                b += 1
    sizes = np.zeros(b)
    for mv in moves:
        sizes[mv.bucket] = mv.nbytes
    bw, sync = 1e4, 0.5
    phases = schedule_phases(moves, fluid_budget(sizes, 1))
    f_from, f_until, f_clock = bucket_windows(phases, bw, b, fluid=True,
                                              sync_s=sync)
    rounds = schedule_rounds(moves, batch=8)
    r_from, r_until, r_clock = round_windows(rounds, bw, b, sync_s=sync)
    assert len(rounds) < len(phases)
    assert r_clock < f_clock
    assert (r_until - r_from).max() <= (f_until - f_from).max() + 1e-9


# ---------------------------------------------------------------------------
# Executor + control-plane integration
# ---------------------------------------------------------------------------

def test_executor_batched_fluid_moves_placement():
    m = 48
    rng = np.random.default_rng(3)
    sizes = rng.integers(256, 4096, m)
    state = BucketedState(
        [{"x": np.zeros(int(sz) // 8, np.float64)} for sz in sizes])
    s = state.bucket_bytes()
    old = Assignment.from_boundaries(m, [0, 24, 48])
    plan = ssm(old, 6, np.ones(m), s, 0.5)
    placement = old.owner_of().copy()
    ex = MigrationExecutor(backend=SimBackend(bw_bytes_per_s=1e6),
                           mode="batched_fluid", fluid_batch=4)
    rep = ex.execute(plan, state, placement)
    assert rep.bytes_moved == pytest.approx(plan.cost)
    n_total = max(plan.old.n_nodes, plan.new.n_nodes)
    np.testing.assert_array_equal(placement,
                                  plan.new.padded(n_total).owner_of())
    assert rep.phases >= 1 and rep.duration_s > 0


def test_control_loop_selects_batched_fluid():
    """Acceptance: on a stock scenario with constrained uplinks (so a
    rebalance cannot fit the pause budget and nodes have more moves than
    fit one batch), the closed loop must pick batched_fluid at least
    once — and record it in the decision trace."""
    sc = SCENARIOS["skew_drift"]()
    sim = SimConfig(interval_s=60.0, bw_bytes_per_s=5e4)
    sv = VectorizedServingSim(sc.m, sim,
                              ElasticPlanner(policy="ssm_numpy", tau=0.4),
                              mode="live", tau=0.4, record_latency=True)
    rep = ControlLoop(sv).run(sc)
    strategies = {d.strategy for d in rep.decisions if d.strategy}
    assert "batched_fluid" in strategies, \
        f"expected a batched_fluid decision, got {strategies}"


def test_chained_dataflow_batched_fluid_stage():
    """batched_fluid runs inside a multi-operator chain: tuples conserve
    per stage and the batched stage actually migrates."""
    from repro.data import node_count_trace, task_state_sizes, task_workloads
    from repro.runtime import ChainedDataflowSim, StageSpec
    m, T = 24, 12
    w = task_workloads(m, T, seed=8)
    s = task_state_sizes(w) * 2000.0
    trace = node_count_trace(w, 3, 6)
    chain = ChainedDataflowSim(m, SimConfig(), [
        StageSpec("map", mode="live"),
        StageSpec("aggregate", mode="batched_fluid", route_seed=3,
                  fluid_batch=4),
    ])
    per_stage = chain.run(w, s, trace)
    d0 = sum(x.delivered for x in per_stage[0])
    np.testing.assert_allclose(d0 + chain.final_queues[0].sum(), w.sum(),
                               rtol=1e-9)
    assert any(x.migration_cost_bytes > 0 for x in per_stage[1])


@pytest.mark.slow
def test_fig12_full_sweep():
    """Full five-strategy benchmark incl. the batched-beats-fluid
    total-migration-time assertion (the fast path runs --smoke via
    scripts/ci.sh)."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    try:
        from benchmarks.fig12_fluid_vs_progressive import main
        main(argv=[])
    finally:
        sys.path.pop(0)
