"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.interval_gain import interval_gain_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,S,hd", [
    (1, 2, 2, 128, 32), (2, 4, 2, 256, 64), (1, 8, 1, 256, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, Hkv, S, hd, causal, window, dtype):
    q = jax.random.normal(KEY, (B, H, S, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Hkv, S, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, S, hd), dtype)
    o = flash_attention_pallas(q, k, v, causal=causal, window=window,
                               q_block=64, kv_block=64, interpret=True)
    r = kref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32), **tol(dtype))


def test_flash_attention_uneven_blocks():
    q = jax.random.normal(KEY, (1, 2, 192, 32))
    k = jax.random.normal(KEY, (1, 2, 192, 32))
    v = jax.random.normal(KEY, (1, 2, 192, 32))
    o = flash_attention_pallas(q, k, v, q_block=64, kv_block=96,
                               interpret=True)
    r = kref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-3,
                               atol=2e-3)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,S,hd", [
    (2, 4, 2, 256, 64), (1, 8, 8, 512, 32), (3, 4, 1, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, Hkv, S, hd, dtype):
    q = jax.random.normal(KEY, (B, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Hkv, S, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, Hkv, S, hd), dtype)
    # ring-buffer style positions with invalid tail
    rng = np.random.default_rng(0)
    fill = rng.integers(S // 4, S, B)
    kv_pos = np.full((B, S), -1, np.int32)
    for b in range(B):
        kv_pos[b, : fill[b]] = np.arange(fill[b])
    q_pos = jnp.asarray(fill - 1, jnp.int32)
    kv_pos = jnp.asarray(kv_pos)
    o = decode_attention_pallas(q, k, v, q_pos, kv_pos, s_block=64,
                                interpret=True)
    r = kref.decode_attention_ref(q, k, v, q_pos, kv_pos)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32), **tol(dtype))


# ---------------------------------------------------------------------------
# rglru / mamba scans
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,D", [(2, 128, 64), (1, 256, 256), (4, 64, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(B, S, D, dtype):
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, S, D))).astype(dtype)
    b = (jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, D)) * 0.1
         ).astype(dtype)
    h0 = jax.random.normal(jax.random.fold_in(KEY, 6), (B, D))
    h = rglru_scan_pallas(a, b, h0, s_block=64, d_block=32, interpret=True)
    r = kref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(
        np.asarray(h, np.float32), np.asarray(r, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("B,S,D,N", [(2, 64, 32, 8), (1, 128, 64, 16)])
def test_mamba_scan(B, S, D, N):
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, S, D, N)))
    b = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, D, N)) * 0.1
    c = jax.random.normal(jax.random.fold_in(KEY, 8), (B, S, N))
    h0 = jax.random.normal(jax.random.fold_in(KEY, 9), (B, D, N))
    y, h_last = mamba_scan_pallas(a, b, c, h0, s_block=32, d_block=16,
                                  interpret=True)
    yr, hr = kref.mamba_scan_ref(a, b, c, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


def test_scan_kernels_match_model_layers():
    """The kernels compute exactly what the model blocks use."""
    from repro.models.recurrence import linear_scan
    a = jax.nn.sigmoid(jax.random.normal(KEY, (2, 128, 48)))
    b = jax.random.normal(KEY, (2, 128, 48))
    h0 = jnp.zeros((2, 48))
    h_model, h_fin = linear_scan(a, b, h0)
    h_kernel = rglru_scan_pallas(a, b, h0, s_block=32, d_block=48,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(h_model), np.asarray(h_kernel),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_fin),
                               np.asarray(h_kernel[:, -1]), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# interval gain (the paper's PMC hot loop)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Qa,Qb,Ka,Kb", [
    (5, 7, 3, 4), (16, 16, 5, 5), (3, 130, 2, 6),
])
def test_interval_gain_vs_numpy_reference(Qa, Qb, Ka, Kb):
    """Kernel == jnp ref == core.mtm.pairwise_gain_matrix (numpy)."""
    from repro.core import prefix_sum
    from repro.core.mtm import pairwise_gain_matrix
    rng = np.random.default_rng(0)
    m = 24
    s = rng.uniform(0.1, 3.0, m)
    Ss = prefix_sum(s)

    def rand_bounds(Q, K):
        out = np.zeros((Q, K + 1), np.int64)
        for q in range(Q):
            cuts = np.sort(rng.choice(np.arange(1, m), K - 1, replace=False))
            out[q] = [0, *cuts.tolist(), m]
        return out

    a = rand_bounds(Qa, Ka)
    b = rand_bounds(Qb, Kb)
    want = pairwise_gain_matrix(a, b, Ss)
    a_lo, a_hi = Ss[a[:, :-1]].astype(np.float32), Ss[a[:, 1:]].astype(
        np.float32)
    b_lo, b_hi = Ss[b[:, :-1]].astype(np.float32), Ss[b[:, 1:]].astype(
        np.float32)
    got_ref = kref.interval_gain_ref(jnp.asarray(a_lo), jnp.asarray(a_hi),
                                     jnp.asarray(b_lo), jnp.asarray(b_hi))
    np.testing.assert_allclose(np.asarray(got_ref), want, rtol=1e-5,
                               atol=1e-5)
    got_k = interval_gain_pallas(jnp.asarray(a_lo), jnp.asarray(a_hi),
                                 jnp.asarray(b_lo), jnp.asarray(b_hi),
                                 tile_a=4, tile_b=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got_k), want, rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("Qa,Qb,tile_a,tile_b", [
    (5, 13, 4, 8),      # 3 padded a-rows, 3 padded b-rows
    (9, 3, 8, 8),       # Qb < tile_b: tb clamps to 3, only a-side pads
    (1, 129, 8, 128),   # production tile shape, 1 a-row, 127 padded b-rows
])
def test_interval_gain_q_padding_sliced_off(Qa, Qb, tile_a, tile_b):
    """Zero-padded Q rows (fabricated lo=hi=0 intervals) must not leak into
    real output cells: the kernel result on non-tile-multiple Qa/Qb equals
    the numpy LCS reference, and is invariant to the tile choice (which is
    the only thing that changes how much padding enters the DP).  Also pads
    the K dim with repeated-m boundaries (PartitionTable's layout) to cover
    the empty-tail-interval case."""
    from repro.core import prefix_sum
    from repro.core.mtm import pairwise_gain_matrix
    rng = np.random.default_rng(7)
    m = 40
    s = rng.uniform(0.1, 3.0, m)
    Ss = prefix_sum(s)
    Ka, Kb = 4, 6

    def rand_bounds(Q, K, pad_to):
        out = np.full((Q, pad_to + 1), m, np.int64)
        out[:, 0] = 0
        for q in range(Q):
            cuts = np.sort(rng.choice(np.arange(1, m), K - 1, replace=False))
            out[q, 1:K] = cuts
        return out

    a = rand_bounds(Qa, Ka, Ka + 2)     # 2 empty tail intervals per row
    b = rand_bounds(Qb, Kb, Kb + 1)
    want = pairwise_gain_matrix(a, b, Ss)
    a_lo, a_hi = Ss[a[:, :-1]], Ss[a[:, 1:]]
    b_lo, b_hi = Ss[b[:, :-1]], Ss[b[:, 1:]]
    args = [jnp.asarray(x, jnp.float32) for x in (a_lo, a_hi, b_lo, b_hi)]
    got = interval_gain_pallas(*args, tile_a=tile_a, tile_b=tile_b,
                               interpret=True)
    assert got.shape == (Qa, Qb)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
    # different tiles → different padding, must be bit-identical after slice
    got2 = interval_gain_pallas(*args, tile_a=1, tile_b=3, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


def test_mtm_aware_plan_through_pallas_gain():
    """mtm_aware_plan(gain_fn=ops.pairwise_gain) picks the same plan as the
    pure-python scoring loop (f32 kernel prunes, exact f64 re-verifies)."""
    from repro.core import (
        Assignment, MTM, PartitionTable, mtm_aware_plan, pmc, prefix_sum,
    )
    rng = np.random.default_rng(3)
    m = 16
    w = rng.uniform(0.5, 2.0, m)
    s = rng.uniform(0.1, 3.0, m)
    table = PartitionTable.build(w, 2, 4, tau=0.8)
    res = pmc(table, s, MTM.uniform(2, 4), gamma=0.7)
    old = Assignment(m, ((0, 6), (6, 11), (11, m), (m, m)))
    kfn = lambda a, b, Ss: ops.pairwise_gain(  # noqa: E731
        a, b, Ss, use_pallas=True, interpret=True)
    for n_new in (2, 3, 4):
        base = mtm_aware_plan(old, n_new, s, res)
        fast = mtm_aware_plan(old, n_new, s, res, gain_fn=kfn)
        assert fast.new.intervals == base.new.intervals
        assert fast.gain == base.gain


def test_pairwise_gain_op_plugs_into_pmc():
    """ops.pairwise_gain is a drop-in gain_fn for core.mtm.pmc."""
    from repro.core import MTM, PartitionTable, pmc, prefix_sum
    rng = np.random.default_rng(1)
    m = 12
    w = rng.uniform(0.5, 2.0, m)
    s = rng.uniform(0.1, 3.0, m)
    table = PartitionTable.build(w, 2, 4, tau=0.8)
    mtm = MTM.uniform(2, 4)
    base = pmc(table, s, mtm, gamma=0.7)
    fast = pmc(table, s, mtm, gamma=0.7,
               gain_fn=lambda a, b, Ss: ops.pairwise_gain(
                   a, b, Ss, use_pallas=True, interpret=True))
    np.testing.assert_allclose(fast.values, base.values, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(fast.cost, base.cost, rtol=1e-4, atol=1e-4)
