"""End-to-end system tests: training loop, checkpoint-restart equivalence,
elastic serving transparency, compressed-gradient training step."""
import numpy as np
import pytest

# jit train-step compiles dominate wall-clock; excluded from the fast path
pytestmark = pytest.mark.slow

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data import SyntheticLM
from repro.models import decode_step, init_cache, init_params, loss_fn, prefill
from repro.optim import OptConfig, adamw_update, init_opt_state


def _train(cfg, steps, params=None, opt_state=None, start=0):
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=5, total_steps=100)
    if params is None:
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = init_opt_state(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch))(params)
        p2, o2, met = adamw_update(grads, opt_state, params, opt_cfg)
        return p2, o2, loss

    losses = []
    for s in range(start, start + steps):
        params, opt_state, loss = step_fn(params, opt_state, ds.batch_at(s))
        losses.append(float(loss))
    return params, opt_state, losses


def test_training_reduces_loss():
    cfg = get_smoke("qwen3-8b")
    _, _, losses = _train(cfg, 30)
    assert losses[-1] < losses[0] - 0.1
    assert all(np.isfinite(losses))


def test_checkpoint_restart_bit_exact():
    """train(10) == train(5) + restore + train(5): elastic restarts replay
    the same stream and state."""
    cfg = get_smoke("olmo-1b")
    p_full, o_full, l_full = _train(cfg, 10)
    p_half, o_half, l_half = _train(cfg, 5)
    p_res, o_res, l_res = _train(cfg, 5, params=p_half, opt_state=o_half,
                                 start=5)
    assert l_half + l_res == pytest.approx(l_full, rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p_full),
                    jax.tree_util.tree_leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5,
                                   atol=1e-6)


def test_elastic_serving_token_transparency():
    """Decode with a mid-stream bucket migration produces tokens identical
    to an uninterrupted run (migration is invisible to the model)."""
    import sys
    sys.path.insert(0, "examples")
    from elastic_serving import run

    ref, _, _ = run(events=False)
    got, _, ctl = run(events=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    kinds = [e.kind for e in ctl.events]
    assert kinds == ["scale", "recover"]


def test_compressed_train_step_converges():
    """Int8 EF gradient compression trains to a similar loss as exact."""
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import make_compressed_train_step
    from repro.optim import init_error_state

    cfg = get_smoke("qwen2.5-3b")
    mesh = make_mesh((1, 1), ("data", "model"))
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=5, total_steps=50)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    err = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    step = make_compressed_train_step(cfg, opt_cfg, mesh, None, None)
    losses = []
    with mesh:
        for s in range(20):
            params, opt_state, err, met = step(
                params, opt_state, err, ds.batch_at(s))
            losses.append(float(met["loss"]))
    assert losses[-1] < losses[0] - 0.05
    assert all(np.isfinite(losses))


def test_microbatched_step_matches_full_batch():
    """Gradient accumulation (f32 accum) reproduces the full-batch step."""
    from repro.launch.steps import make_train_step
    cfg = get_smoke("olmo-1b")
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                        weight_decay=0.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    batch = ds.batch_at(0)
    full = make_train_step(cfg, opt_cfg)
    micro = make_train_step(cfg, opt_cfg, microbatches=4)
    p1, o1, m1 = jax.jit(full)(params, init_opt_state(params), batch)
    p2, o2, m2 = jax.jit(micro)(params, init_opt_state(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)
