"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED config of the same family
and runs one forward + one train-grad step + one prefill/decode step on CPU,
asserting output shapes and the absence of NaNs.  The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation) — here we
only check their static invariants (dims, analytic param counts).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# model compiles dominate suite wall-clock; excluded from the fast path
pytestmark = pytest.mark.slow

from repro.configs import (
    ARCH_IDS, SHAPES, get_config, get_smoke, input_specs, shape_applicable,
    smoke_batch,
)
from repro.models import (
    decode_step, forward, init_cache, init_params, loss_fn, prefill,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, KEY)
    batch = smoke_batch(cfg)
    logits = forward(params, cfg, batch)
    S = batch["tokens"].shape[1]
    extra = cfg.vision_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (2, S + extra, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves and all(not bool(jnp.isnan(g).any()) for g in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, KEY)
    batch = smoke_batch(cfg)
    B, S = batch["tokens"].shape
    cache = init_cache(cfg, B, 2 * S)
    lg, cache = prefill(params, cfg, batch, cache)
    assert lg.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg2, cache = decode_step(params, cfg, tok,
                             jnp.full((B,), S, jnp.int32), cache)
    assert lg2.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(lg2).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Greedy prefill+decode logits == full-sequence forward logits."""
    cfg = get_smoke(arch)
    params = init_params(cfg, KEY)
    batch = smoke_batch(cfg)
    B, S = batch["tokens"].shape
    full = forward(params, cfg, batch)
    extra = cfg.vision_tokens if cfg.family == "vlm" else 0

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : S - 1]
    cache = init_cache(cfg, B, 2 * S)
    lg, cache = prefill(params, cfg, pre, cache)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, extra + S - 2]),
        rtol=5e-2, atol=5e-2)
    lg2, _ = decode_step(params, cfg, batch["tokens"][:, S - 1 : S],
                         jnp.full((B,), extra + S - 1, jnp.int32)
                         if extra else jnp.full((B,), S - 1, jnp.int32),
                         cache)
    np.testing.assert_allclose(
        np.asarray(lg2[:, 0]), np.asarray(full[:, extra + S - 1]),
        rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_static_invariants(arch):
    cfg = get_config(arch)
    assert cfg.n_layers == len(cfg.layer_kinds)
    if cfg.family != "ssm":
        assert cfg.n_heads % cfg.n_kv_heads == 0
    n = cfg.n_params()
    assert n > 1e8, f"{arch}: suspicious param count {n}"
    # spot checks against the published sizes (±20%: analytic count)
    expected = {
        "qwen2.5-32b": 32e9, "qwen3-8b": 8e9, "olmo-1b": 1.2e9,
        "qwen2.5-3b": 3e9, "falcon-mamba-7b": 7e9,
        "mixtral-8x7b": 47e9, "phi3.5-moe-42b-a6.6b": 42e9,
        "recurrentgemma-9b": 9e9, "whisper-large-v3": 1.5e9,
        "internvl2-2b": 2e9,
    }[arch]
    assert 0.6 * expected < n < 1.55 * expected, (arch, n, expected)


def test_shape_applicability_matrix():
    """The 40-cell matrix: long_500k runs only for sub-quadratic archs."""
    runnable = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if shape.name == "long_500k":
                expect = arch in ("recurrentgemma-9b", "mixtral-8x7b",
                                  "falcon-mamba-7b")
                assert ok == expect, (arch, ok, why)
            else:
                assert ok
            runnable += ok
    assert runnable == 33  # 40 cells - 7 skipped long_500k


def test_input_specs_are_abstract():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
