"""Optimizer + data pipeline + gradient compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from repro.data import SyntheticLM, host_shard_batch, task_workloads
from repro.data.streaming import node_count_trace, task_state_sizes
from repro.optim import (
    OptConfig, adamw_update, compressed_psum_mean, init_error_state,
    init_opt_state, lr_at, quantize_int8, dequantize_int8,
)


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # peak after warmup
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)  # min_lr_frac * lr
    assert all(a >= b - 1e-12 for a, b in zip(lrs[1:], lrs[2:]))


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.array([3.0, -2.0], jnp.bfloat16)}
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, clip_norm=10.0)
    state = init_opt_state(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"].astype(jnp.float32)))
    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, metrics = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2 * l0
    assert int(state["step"]) == 100


def test_adamw_master_weights_precision():
    """bf16 params follow the f32 master copy (no bf16 update dead-zone)."""
    params = {"w": jnp.full((4,), 100.0, jnp.bfloat16)}
    cfg = OptConfig(lr=1e-4, warmup_steps=0, weight_decay=0.0,
                    clip_norm=1e9)
    state = init_opt_state(params)
    for _ in range(50):
        g = {"w": jnp.ones((4,), jnp.float32)}
        params, state, _ = adamw_update(g, state, params, cfg)
    # 50 steps * ~1e-4 lr: master moved ~5e-3 even though bf16 ulp@100 ≈ 0.5
    assert float(state["master"]["w"][0]) < 100.0 - 1e-3


@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_bounded(seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(0, 5, 64).astype(np.float32))
    q, s = quantize_int8(v)
    err = np.abs(np.asarray(dequantize_int8(q, s) - v))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_compressed_psum_error_feedback():
    """EF compression: per-step error bounded, and the *accumulated* applied
    sum tracks the true sum (residual does not drift)."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, 128)
                          .astype(np.float32))}
    err = init_error_state(g)

    @jax.jit
    def step(g, err):
        f = shard_map(
            lambda gg, ee: compressed_psum_mean(gg, ee, "data"),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False)
        return f(g, err)

    applied = jnp.zeros_like(g["w"])
    true = jnp.zeros_like(g["w"])
    for i in range(20):
        gi = {"w": g["w"] * (1.0 + 0.1 * i)}
        mean, err = step(gi, err)
        applied = applied + mean["w"]
        true = true + gi["w"]
    # error feedback: cumulative applied == cumulative true up to one scale
    resid = np.abs(np.asarray(applied - true))
    scale = float(jnp.max(jnp.abs(g["w"])) * 3 / 127)
    assert resid.max() < 2 * scale


def test_synthetic_lm_determinism_and_sharding():
    ds = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=8, seed=1)
    b1, b2 = ds.batch_at(5), ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 16)
    assert b1["tokens"].dtype == np.int32
    # host sharding slices rows
    sh = host_shard_batch(b1, 4, 2)
    np.testing.assert_array_equal(sh["tokens"], b1["tokens"][4:6])
    # different steps differ
    assert not np.array_equal(ds.batch_at(5)["tokens"],
                              ds.batch_at(6)["tokens"])
    # prefetch iterator yields the same stream
    it = ds.batches(start_step=5)
    nxt = next(it)
    np.testing.assert_array_equal(nxt["tokens"], b1["tokens"])


def test_bursty_stream_properties():
    w = task_workloads(32, 120, seed=3)
    assert w.shape == (120, 32)
    assert (w >= 0).all()
    # skew: top task way above median
    mean_w = w.mean(axis=0)
    assert mean_w.max() > 5 * np.median(mean_w)
    s = task_state_sizes(w)
    assert s.shape == w.shape and (s >= 0).all()
    trace = node_count_trace(w, 8, 16)
    assert trace.min() >= 8 and trace.max() <= 16
    assert len(np.unique(trace)) > 1        # elasticity actually happens
