"""Elastic runtime tests: migration executor, phases, checkpoint-restore,
failure recovery, stragglers, live serving, word-count correctness."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Assignment, ElasticPlanner, migration_cost, ssm
from repro.runtime import (
    BucketedState, CheckpointManager, ElasticController, ElasticServingSim,
    ElasticWordCount, MigrationExecutor, SimBackend, SimConfig, SpeedTracker,
    move_list, naive_duration, phase_duration, physical_migration_cost,
    plan_to_permutation, recovery_plan, restored_bytes, route,
    schedule_phases, weighted_plan,
)
from repro.runtime.state import owner_lookup


def mk_state(m, nbytes_per_bucket=None, seed=0):
    rng = np.random.default_rng(seed)
    sizes = (nbytes_per_bucket if nbytes_per_bucket is not None
             else rng.integers(64, 4096, m))
    return BucketedState(
        [{"x": np.zeros(int(sz) // 8, np.float64)} for sz in sizes])


# ---------------------------------------------------------------------------
# Phase scheduling (Rödiger-style)
# ---------------------------------------------------------------------------

@given(m=st.integers(8, 48), n_old=st.integers(2, 6), n_new=st.integers(2, 8),
       seed=st.integers(0, 999))
@settings(max_examples=40, deadline=None)
def test_phase_schedule_complete_and_balanced(m, n_old, n_new, seed):
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.choice(np.arange(1, m), n_old - 1, replace=False))
    old = Assignment.from_boundaries(m, [0, *cuts.tolist(), m])
    w = rng.uniform(0.5, 2.0, m)
    s = rng.uniform(100, 10_000, m)
    plan = ssm(old, n_new, w, s, 1.0)
    moves = move_list(plan, s)
    phases = schedule_phases(moves)
    # every move scheduled exactly once
    flat = [mv for ph in phases for mv in ph]
    assert sorted(mv.bucket for mv in flat) == sorted(
        mv.bucket for mv in moves)
    # phase budget property: per-phase per-node traffic <= default budget
    if moves:
        endpoints = {mv.src for mv in moves} | {mv.dst for mv in moves}
        budget = max(max(mv.nbytes for mv in moves),
                     sum(mv.nbytes for mv in moves) / max(len(endpoints), 1))
    else:
        budget = 0
    for ph in phases:
        up, down = {}, {}
        for mv in ph:
            up[mv.src] = up.get(mv.src, 0) + mv.nbytes
            down[mv.dst] = down.get(mv.dst, 0) + mv.nbytes
        for v in list(up.values()) + list(down.values()):
            assert v <= budget + 1e-9
    # scheduled duration never exceeds the naive serial transfer
    bw = 1e9
    assert sum(phase_duration(p, bw) for p in phases) <= \
        naive_duration(moves, bw) + 1e-12


def test_executor_moves_placement_and_accounts_bytes():
    m = 32
    state = mk_state(m)
    s = state.bucket_bytes()
    old = Assignment.from_boundaries(m, [0, 16, 32])
    plan = ssm(old, 4, np.ones(m), s, 0.5)
    placement = old.owner_of().copy()
    ex = MigrationExecutor(backend=SimBackend(bw_bytes_per_s=1e6),
                           mode="live")
    rep = ex.execute(plan, state, placement)
    assert rep.bytes_moved == pytest.approx(plan.cost)
    # placement now matches the new assignment
    n_total = max(plan.old.n_nodes, plan.new.n_nodes)
    np.testing.assert_array_equal(placement,
                                  plan.new.padded(n_total).owner_of())
    assert rep.duration_s > 0 and rep.phases >= 1


def test_progressive_bounds_inflight():
    m = 64
    state = mk_state(m, nbytes_per_bucket=np.full(m, 1000))
    s = state.bucket_bytes()
    old = Assignment.from_boundaries(m, [0, 64])          # everything on N0
    plan = ssm(old, 8, np.ones(m), s, 0.2)
    placement = old.owner_of().copy()
    ex = MigrationExecutor(backend=SimBackend(), mode="progressive",
                           max_inflight=2)
    rep = ex.execute(plan, state, placement)
    assert rep.suspended_peak <= 2
    ex2 = MigrationExecutor(backend=SimBackend(), mode="live")
    rep2 = ex2.execute(plan, state, old.owner_of().copy())
    # mini-migrations trade more phases for bounded suspension (paper §5.2)
    assert rep.phases >= rep2.phases
    assert rep2.suspended_peak >= rep.suspended_peak


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def test_route_stable_and_uniform():
    keys = np.arange(100_000)
    b1, b2 = route(keys, 64), route(keys, 64)
    np.testing.assert_array_equal(b1, b2)
    counts = np.bincount(b1, minlength=64)
    assert counts.min() > 0.7 * counts.mean()
    assert counts.max() < 1.3 * counts.mean()


def test_owner_lookup_matches_assignment():
    a = Assignment.from_boundaries(16, [0, 5, 11, 16])
    bounds = [iv[0] for iv in a.intervals] + [16]
    ids = np.arange(16)
    np.testing.assert_array_equal(owner_lookup(bounds[:-1] + [16], ids)
                                  if False else
                                  owner_lookup([0, 5, 11], ids), a.owner_of())


# ---------------------------------------------------------------------------
# Checkpoint-restore with resharding
# ---------------------------------------------------------------------------

def test_checkpoint_restore_reshards(tmp_path):
    m = 24
    state = mk_state(m, seed=3)
    for j, b in enumerate(state.buckets):
        b["x"][:] = j                                  # identifiable content
    a = Assignment.from_boundaries(m, [0, 8, 16, 24])
    cm = CheckpointManager(tmp_path, keep=2)
    cm.save(10, state, a)
    assert cm.latest() == 10
    w = np.ones(m)
    restored, new_assign, report, _ = cm.restore(10, 5, w, tau=0.5)
    assert sum(1 for lo, hi in new_assign.intervals if hi > lo) == 5
    # content preserved
    for j in range(m):
        assert float(restored.buckets[j]["x"][0]) == j
    # resident + read == total
    total = state.bucket_bytes().sum()
    assert report.bytes_read + report.bytes_resident == pytest.approx(total)
    # going 4 -> 5 nodes keeps most bytes resident (optimal restore)
    assert report.bytes_resident > 0.5 * total


def test_checkpoint_async_and_gc(tmp_path):
    m = 8
    state = mk_state(m)
    a = Assignment.from_boundaries(m, [0, 4, 8])
    cm = CheckpointManager(tmp_path, keep=2)
    for step in (1, 2, 3):
        cm.save(step, state, a, extra={"step": np.asarray(step)},
                async_=True)
    cm.wait()
    assert cm.steps() == [2, 3]                        # keep=2 GC


# ---------------------------------------------------------------------------
# Failure recovery + stragglers
# ---------------------------------------------------------------------------

def test_recovery_keeps_survivor_state():
    m = 32
    rng = np.random.default_rng(0)
    s = rng.uniform(100, 1000, m)
    w = np.ones(m)
    old = Assignment.from_boundaries(m, [0, 8, 16, 24, 32])
    plan = recovery_plan(old, {1}, 3, w, s, tau=0.8)
    # failed node 1 owns nothing afterwards
    assert plan.new.intervals[1][1] <= plan.new.intervals[1][0]
    # network cost counts only survivor-owned buckets that move
    owner = old.owner_of()
    survivor_bytes = s[owner != 1].sum()
    assert plan.cost <= survivor_bytes
    assert restored_bytes(old, {1}, s) == pytest.approx(s[owner == 1].sum())
    # the balance requirement holds over the 3 surviving active nodes
    loads = plan.new.node_loads(w)
    cap = (1 + 0.8) * w.sum() / 3
    assert (loads <= cap + 1e-9).all()


def test_speed_tracker_and_weighted_plan():
    st_ = SpeedTracker(4)
    st_.update([1.0, 1.0, 1.0, 3.0])
    st_.update([1.0, 1.1, 0.9, 3.2])
    assert st_.stragglers() == [3]
    speeds = st_.speeds()
    assert speeds[3] < 0.5

    m = 48
    rng = np.random.default_rng(1)
    w = rng.uniform(0.5, 2.0, m)
    s = rng.uniform(100, 1000, m)
    old = Assignment.from_boundaries(m, [0, 12, 24, 36, 48])
    v_plan, phys_map = weighted_plan(old, speeds, w, s, tau=0.4)
    # straggler's physical share shrinks below fair share
    v_of = [p for p, vs in enumerate(phys_map) for _ in vs]
    # reconstruct v_of in slot order
    v_of = np.zeros(max(v for vs in phys_map for v in vs) + 1, int)
    for p, vs in enumerate(phys_map):
        for v in vs:
            v_of[v] = p
    loads = np.zeros(4)
    Sw = np.concatenate([[0], np.cumsum(w)])
    for v, iv in enumerate(v_plan.new.intervals):
        if iv[1] > iv[0] and v < len(v_of):
            loads[v_of[v]] += Sw[iv[1]] - Sw[iv[0]]
    fair = w.sum() / 4
    assert loads[3] < 0.8 * fair
    # physical cost <= virtual-plan cost (intra-node moves are free)
    assert physical_migration_cost(v_plan, list(v_of), s) <= v_plan.cost + 1e-9


# ---------------------------------------------------------------------------
# Controller + serving sim + wordcount
# ---------------------------------------------------------------------------

def test_controller_scale_rebalance_recover_history():
    m = 32
    state = mk_state(m)
    ctl = ElasticController(m, 2, tau=0.8)
    w = np.ones(m)
    ctl.scale(4, w, state)
    assert ctl.n_nodes == 4
    w2 = np.ones(m)
    w2[:4] = 20.0
    assert ctl.balance_violated(w2)
    ctl.maybe_rebalance(w2, state)
    assert not ctl.balance_violated(w2)
    ctl.recover({0}, w2, state)
    assert ctl.n_nodes == 3
    assert [e.kind for e in ctl.events] == ["scale", "rebalance", "recover"]
    mtm = ctl.estimate_mtm(2, 4)
    assert mtm.probs.shape == (3, 3)


def test_live_beats_kill_restart():
    """Fig. 11 shape: live migration's response time is orders of magnitude
    below kill-restart during migration intervals."""
    from repro.data import task_workloads, task_state_sizes, node_count_trace
    m = 32
    w = task_workloads(m, 30, seed=5)
    s = task_state_sizes(w) * 2000          # sizeable state
    trace = node_count_trace(w, 4, 8)
    sim = SimConfig()
    planner = ElasticPlanner(policy="ssm", tau=None) if False else \
        ElasticPlanner(policy="ssm")
    results = {}
    for mode in ("kill_restart", "live", "progressive"):
        sv = ElasticServingSim(m, sim, ElasticPlanner(policy="ssm"),
                               mode=mode)
        mets = sv.run(w, s, trace)
        mig = [x for x in mets if x.migration_cost_bytes > 0]
        results[mode] = np.mean([x.mean_response_s for x in mig])
    assert results["live"] < 0.25 * results["kill_restart"]
    assert results["progressive"] < results["kill_restart"]


def test_wordcount_counts_survive_migration():
    rng = np.random.default_rng(0)
    app = ElasticWordCount(m=16, n_nodes=2)
    words = rng.integers(0, 500, 5000)
    app.ingest(words)
    before = app.totals()
    plan, rep = app.scale(5)
    assert sum(1 for lo, hi in app.assign.intervals if hi > lo) == 5
    after = app.totals()
    assert before == after                    # no state lost in migration
    truth = {int(k): int(c) for k, c in
             zip(*np.unique(words, return_counts=True))}
    assert after == truth
    assert rep.bytes_moved < app.state.bucket_bytes().sum()  # partial move


def test_migration_step_permutation():
    m = 16
    old = Assignment.from_boundaries(m, [0, 8, 16])
    plan = ssm(old, 4, np.ones(m), np.ones(m), 0.5)
    perm = plan_to_permutation(plan)
    assert sorted(perm.tolist()) == list(range(m))
    import jax.numpy as jnp
    from repro.runtime import make_migration_step
    step = make_migration_step(m)
    x = jnp.arange(m * 3, dtype=jnp.float32).reshape(m, 3)
    y = step(x, jnp.asarray(perm))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x)[perm])
