"""Node-loss recovery (runtime/ft.py) wired through VectorizedServingSim.

Scenario: 4 nodes serve m=64 buckets under a uniform workload; node 1 dies
at interval 6 (the node trace drops 4 -> 3 at the same instant).  The sim
routes the event through ft.recovery_plan / ft.restored_bytes:

* the checkpoint read is exactly the dead node's state bytes,
* SSM keeps every survivor's state in place (optimal network cost 0 here:
  the lost buckets plan at s=0, so a contiguous re-cover of [16, 32) by a
  neighbour survivor is free),
* serving continues in every interval, with no migration thrash afterwards.

Uniform w keeps the initial linspace cuts exactly balanced, so no migration
fires before the failure and the pre-failure assignment — hence the dead
node's bucket range [16, 32) — is known in closed form.
"""
import numpy as np
import pytest

from repro.core import ElasticPlanner
from repro.runtime.serving import SimConfig
from repro.runtime.simulator import VectorizedServingSim

M, T, T_FAIL, DEAD = 64, 12, 6, 1


def test_vectorized_sim_node_loss_recovery():
    rng = np.random.default_rng(0)
    w = np.ones((T, M))
    s = rng.uniform(0.1, 3.0, (T, M))
    trace = [4] * T_FAIL + [3] * (T - T_FAIL)
    sim = VectorizedServingSim(
        M, SimConfig(interval_s=10.0, slots_per_interval=10),
        ElasticPlanner(policy="ssm"), mode="live", tau=0.8,
        failures={T_FAIL: {DEAD}})
    mets = sim.run(w, s, trace)
    assert len(mets) == T

    # before the failure: steady state, nothing restored, nothing migrated
    for met in mets[:T_FAIL]:
        assert met.restored_bytes == 0.0
        assert met.migration_cost_bytes == 0.0

    rec = mets[T_FAIL]
    # node 1 owned buckets [16, 32) since t=0; its state is the checkpoint
    # read, charged in the failure interval and nowhere else
    assert rec.restored_bytes == pytest.approx(s[T_FAIL, 16:32].sum())
    # SSM recovery is optimal: the lost range re-covers for free (s=0), the
    # survivors keep their state — zero network migration bytes
    assert rec.migration_cost_bytes == pytest.approx(0.0)

    # after the failure: 3 survivors are balanced, no replan thrash
    for met in mets[T_FAIL + 1:]:
        assert met.restored_bytes == 0.0
        assert met.migration_cost_bytes == 0.0

    # the stream kept flowing through the loss
    for met in mets:
        assert met.delivered > 0.0
