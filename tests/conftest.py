"""Test-suite bootstrap.

* Falls back to the vendored minimal hypothesis shim (tests/_vendor) when
  the real ``hypothesis`` package is not installed, so the property-test
  modules collect and run on a bare jax+numpy+pytest container.  Install
  requirements-dev.txt for full Hypothesis runs (shrinking etc.).
* Registers the tier marker split (see pytest.ini): ``slow`` tests are the
  jit/pallas/model-smoke heavyweights; ``-m "not slow"`` is the fast path.
"""
import sys
from pathlib import Path

try:
    import hypothesis  # noqa: F401  (prefer the real package when present)
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parent / "_vendor"))
