"""The negative space of the plan verifier: every PLN rule must fire on a
hand-corrupted plan — and fire *alone*, so rule IDs stay meaningful — and
real planner output must verify clean under every strategy.

Corruptions (one per rule, per the invariant catalog in
``analysis/plancheck.py``):

* PLN001 — a move dropped from / duplicated in the schedule, and a stale
  ``plan.old``.
* PLN002 — valid rounds that leave a schedulable link idle (non-maximal).
* PLN003 — a doctored ``plan.cost`` (bytes no longer conserved).
* PLN004 — a structurally-valid plan that overloads one node past
  (1+τ)W/n.
* PLN005 — a pause window pushed outside [0, duration] and a pause on a
  bucket that does not move.
* PLN006 — a permutation with the contiguity broken / an index doubled.
"""
import numpy as np
import pytest

from repro.analysis import (
    PLN_RULES, PlanVerificationError, assert_clean, check_moves,
    check_permutation, check_plan, check_schedule, check_windows,
    verify_migration,
)
from repro.core import (
    Assignment, ElasticPlanner, MigrationPlan, migration_cost,
    migration_gain,
)
from repro.runtime import SimConfig
from repro.runtime.migration import (
    move_list, plan_to_permutation, schedule_rounds, strategy_schedule,
)
from repro.runtime.serving import SERVING_MODES, strategy_windows


def _even(m, n):
    cuts = np.linspace(0, m, n + 1).round().astype(int)
    return Assignment.from_boundaries(m, list(cuts))


def _honest_plan(old, new, s):
    """A MigrationPlan whose gain/cost books are true for (old, new, s)."""
    return MigrationPlan(old=old, new=new,
                         gain=migration_gain(old, new, s),
                         cost=migration_cost(old, new, s))


@pytest.fixture
def setup():
    rng = np.random.default_rng(42)
    m = 48
    w = rng.pareto(1.5, m) + 0.1
    s = rng.pareto(1.5, m) * 1e6 + 1e5
    planner = ElasticPlanner(policy="ssm")
    old = _even(m, 4)
    plan = planner.plan(old, 6, w, s, tau=0.4)
    return m, w, s, planner, old, plan


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# The positive space first: real plans are clean under every strategy
# ---------------------------------------------------------------------------

def test_real_plans_verify_clean_all_strategies(setup):
    m, w, s, planner, old, plan = setup
    for mode in SERVING_MODES:
        findings = verify_migration(
            plan, s, mode=mode, fluid_batch=4, w=w, tau=0.4, n_target=6,
            relax_tau_max=planner.relax_tau_max, expected_old=old)
        assert findings == [], f"{mode}: {[str(f) for f in findings]}"


def test_rule_catalog_is_complete():
    assert sorted(PLN_RULES) == [f"PLN00{i}" for i in range(1, 7)]


# ---------------------------------------------------------------------------
# PLN001 — coverage & ownership
# ---------------------------------------------------------------------------

def test_dropped_move_fires_pln001(setup):
    m, w, s, planner, old, plan = setup
    moves = move_list(plan, s)
    schedule = strategy_schedule(moves, s, "live")
    schedule[0] = schedule[0][1:]           # drop one move from a phase
    findings = check_schedule(moves, schedule, "live")
    assert rules_of(findings) == {"PLN001"}
    assert any("dropped" in f.message for f in findings)


def test_duplicated_bucket_fires_pln001(setup):
    m, w, s, planner, old, plan = setup
    moves = move_list(plan, s)
    findings = check_moves(plan, s, moves + [moves[0]])
    assert rules_of(findings) == {"PLN001"}
    assert any("duplicate" in f.message for f in findings)


def test_stale_old_assignment_fires_pln001(setup):
    m, w, s, planner, old, plan = setup
    live = _even(m, 5)                      # not the assignment planned from
    findings = check_plan(plan, s, expected_old=live)
    assert rules_of(findings) == {"PLN001"}
    assert any("stale" in f.message for f in findings)


# ---------------------------------------------------------------------------
# PLN002 — maximal matching rounds
# ---------------------------------------------------------------------------

def test_non_maximal_round_fires_pln002():
    from repro.runtime import Move
    moves = [Move(bucket=0, src=0, dst=1, nbytes=100.0),
             Move(bucket=1, src=2, dst=3, nbytes=100.0)]
    # both links are endpoint-disjoint, so a correct matching ships both in
    # ONE round; splitting them is valid coverage but not maximal
    lazy = [[moves[0]], [moves[1]]]
    findings = check_schedule(moves, lazy, "batched_fluid")
    assert rules_of(findings) == {"PLN002"}
    assert any("not maximal" in f.message for f in findings)
    # and the real scheduler's output is clean
    assert_clean(check_schedule(moves, schedule_rounds(moves, batch=1),
                                "batched_fluid"))


def test_conflicting_round_fires_pln002():
    from repro.runtime import Move
    moves = [Move(bucket=0, src=0, dst=1, nbytes=100.0),
             Move(bucket=1, src=0, dst=2, nbytes=100.0)]
    both_at_once = [[moves[0], moves[1]]]   # node 0 sends on two links
    findings = check_schedule(moves, both_at_once, "batched_fluid")
    assert rules_of(findings) == {"PLN002"}
    assert any("sends to both" in f.message for f in findings)


# ---------------------------------------------------------------------------
# PLN003 — byte conservation
# ---------------------------------------------------------------------------

def test_doctored_cost_fires_pln003(setup):
    m, w, s, planner, old, plan = setup
    lying = MigrationPlan(old=plan.old, new=plan.new, gain=plan.gain,
                          cost=plan.cost * 0.5)
    findings = check_plan(lying, s)
    assert rules_of(findings) == {"PLN003"}


def test_mispriced_move_fires_pln003(setup):
    m, w, s, planner, old, plan = setup
    moves = move_list(plan, s)
    bad = list(moves)
    mv = bad[0]
    bad[0] = type(mv)(bucket=mv.bucket, src=mv.src, dst=mv.dst,
                      nbytes=mv.nbytes * 3.0)
    findings = check_moves(plan, s, bad)
    assert rules_of(findings) == {"PLN003"}


# ---------------------------------------------------------------------------
# PLN004 — capacity feasibility (Definition 2.1)
# ---------------------------------------------------------------------------

def test_over_cap_node_fires_pln004():
    m = 8
    w = np.ones(m)
    s = np.full(m, 100.0)
    old = _even(m, 2)
    # one node hoards 7 of 8 unit-load buckets: load 7 > (1+0.2)·8/2 = 4.8
    new = Assignment.from_boundaries(m, [0, 7, 8])
    plan = _honest_plan(old, new, s)        # books are true → no PLN003
    findings = check_plan(plan, s, w=w, tau=0.2, n_target=2)
    assert rules_of(findings) == {"PLN004"}
    # the same plan is fine at a τ that allows the skew
    assert check_plan(plan, s, w=w, tau=10.0, n_target=2) == []


def test_relax_ceiling_suppresses_pln004():
    """A planner allowed to relax τ (relax_tau_max) must not be flagged at
    the requested τ — only past the relax ceiling."""
    m = 8
    w = np.ones(m)
    s = np.full(m, 100.0)
    plan = _honest_plan(_even(m, 2), Assignment.from_boundaries(m, [0, 7, 8]),
                        s)
    strict = check_plan(plan, s, w=w, tau=0.2, n_target=2)
    assert rules_of(strict) == {"PLN004"}
    relaxed = check_plan(plan, s, w=w, tau=0.2, n_target=2,
                         relax_tau_max=8.0)
    assert relaxed == []


# ---------------------------------------------------------------------------
# PLN005 — window containment & pauses
# ---------------------------------------------------------------------------

def test_window_outside_interval_fires_pln005(setup):
    m, w, s, planner, old, plan = setup
    sim = SimConfig()
    moves = move_list(plan, s)
    un_from, un_until, duration, freeze = strategy_windows(
        moves, s, sim, "live", 4, 1, m)
    bad_until = un_until.copy()
    bad_until[moves[0].bucket] = duration + 5.0     # past the interval end
    findings = check_windows(moves, un_from, bad_until, duration, freeze,
                             "live", sim.bw_bytes_per_s, m)
    assert rules_of(findings) == {"PLN005"}
    assert any("outside the migration interval" in f.message
               for f in findings)


def test_pausing_a_nonmover_fires_pln005(setup):
    m, w, s, planner, old, plan = setup
    sim = SimConfig()
    moves = move_list(plan, s)
    un_from, un_until, duration, freeze = strategy_windows(
        moves, s, sim, "live", 4, 1, m)
    movers = {mv.bucket for mv in moves}
    stayer = next(j for j in range(m) if j not in movers)
    bad_until = un_until.copy()
    bad_until[stayer] = duration * 0.5              # pause a to-stay bucket
    findings = check_windows(moves, un_from, bad_until, duration, freeze,
                             "live", sim.bw_bytes_per_s, m)
    assert rules_of(findings) == {"PLN005"}
    assert any("does not move" in f.message for f in findings)


# ---------------------------------------------------------------------------
# PLN006 — permutation validity
# ---------------------------------------------------------------------------

def test_swapped_permutation_fires_pln006(setup):
    m, w, s, planner, old, plan = setup
    perm = plan_to_permutation(plan).copy()
    perm[0], perm[-1] = perm[-1], perm[0]   # breaks per-node contiguity
    findings = check_permutation(plan, perm)
    assert rules_of(findings) == {"PLN006"}


def test_doubled_index_fires_pln006(setup):
    m, w, s, planner, old, plan = setup
    perm = plan_to_permutation(plan).copy()
    perm[1] = perm[0]                       # no longer a bijection
    findings = check_permutation(plan, perm)
    assert rules_of(findings) == {"PLN006"}
    assert any("not a permutation" in f.message for f in findings)


def test_real_permutation_is_clean(setup):
    m, w, s, planner, old, plan = setup
    assert check_permutation(plan) == []


# ---------------------------------------------------------------------------
# Reporting plumbing
# ---------------------------------------------------------------------------

def test_assert_clean_raises_with_rule_ids(setup):
    m, w, s, planner, old, plan = setup
    lying = MigrationPlan(old=plan.old, new=plan.new, gain=plan.gain,
                          cost=plan.cost * 2.0)
    with pytest.raises(PlanVerificationError, match="PLN003"):
        assert_clean(check_plan(lying, s), where="unit-test")


def test_executor_strict_verify_rejects_corrupt_plan():
    """MigrationExecutor(verify='strict') refuses to execute a plan whose
    books are wrong, and executes an honest one normally."""
    from repro.runtime import BucketedState, MigrationExecutor, SimBackend
    m = 16
    state = BucketedState([{"x": np.zeros(64, np.float64)}
                           for _ in range(m)])
    s = state.bucket_bytes()
    old = _even(m, 2)
    new = _even(m, 4)
    plan = _honest_plan(old, new, s)
    placement = old.owner_of().copy()
    ex = MigrationExecutor(backend=SimBackend(), mode="live",
                           verify="strict")
    rep = ex.execute(plan, state, placement)        # honest: runs fine
    assert rep.bytes_moved == pytest.approx(plan.cost)
    lying = MigrationPlan(old=old, new=new, gain=plan.gain,
                          cost=plan.cost + 12345.0)
    with pytest.raises(PlanVerificationError, match="PLN003"):
        ex.execute(lying, state, old.owner_of().copy())


def test_sim_strict_verify_runs_clean():
    """ElasticServingSim(verify='strict') over a scale event: the in-loop
    hook sees only clean plans on real planner output."""
    from repro.runtime import ElasticServingSim
    m = 32
    rng = np.random.default_rng(0)
    w = rng.pareto(1.5, (4, m)) + 0.1
    s = rng.pareto(1.5, (4, m)) * 1e4 + 1e3
    sv = ElasticServingSim(m, SimConfig(), ElasticPlanner(policy="ssm"),
                           mode="fluid", verify="strict")
    mets = sv.run(w, s, [2, 3, 3, 2])
    assert len(mets) == 4
