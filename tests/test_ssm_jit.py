"""jit SSM backend gates: oracle agreement across all solvers and
Infeasible consistency at cap boundaries.

The heavy differential sweep lives in benchmarks/ssm_oracles.py (one
harness, N solvers — also run by ``scripts/ci.sh fast``); the tests here
import it so the comparison logic cannot drift from the benchmark."""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.ssm_oracles import (  # noqa: E402
    INFEASIBLE, SOLVERS, _agrees, _answer, crafted_instances,
    random_instance, run,
)
from repro.core.intervals import Assignment  # noqa: E402


@pytest.mark.slow
def test_oracle_harness_50_plus_randomized_instances():
    """brute/simple/ssm_numpy/ssm_jit agree (feasibility exactly, gain to
    rtol 1e-9) on 52 randomized + 4 crafted instances.  Raises on any
    disagreement."""
    gains = run(n_tiny=20, n_big=32, seed=0, verbose=False)
    assert len(gains["ssm_jit"]) >= 54
    assert len(gains["simple"]) == len(gains["ssm_jit"])


def test_quick_jit_vs_simple_agreement():
    """Fast-tier smoke: a dozen tiny randomized instances, jit vs simple."""
    rng = np.random.default_rng(42)
    for _ in range(12):
        inst = random_instance(rng, tiny=True)
        got = _answer(SOLVERS["ssm_jit"], inst)
        ref = _answer(SOLVERS["simple"], inst)
        assert _agrees(got, ref), (inst, got, ref)


def test_cap_boundary_crafted_instances_consistent():
    """The satellite-3 regression set: exact-cap single task, over-cap
    task, n' below the min cover count, all-zero weights."""
    for inst in crafted_instances():
        tiny = inst[0].m <= 20
        answers = {name: _answer(fn, inst)
                   for name, fn in SOLVERS.items()
                   if name != "brute" or tiny}
        ref = answers["simple"]
        for name, got in answers.items():
            assert _agrees(got, ref), (name, got, ref)


def test_exact_cap_crossing_all_solvers_agree():
    """Sweep a single hot task's weight across the cap: with n'=2, τ=0.25,
    w=[x,1,1,1] the cap (1+τ)(x+3)/2 equals x exactly at x=5.0.  Every
    solver (brute included, m=4) must flip feasibility at the same x —
    the unified feasible_tol predicate is what guarantees it."""
    s = np.array([2.0, 1.0, 1.0, 1.0])
    old = Assignment.from_boundaries(4, [0, 2, 4])
    for x in (5.0, np.nextafter(5.0, 4.0), np.nextafter(5.0, 6.0),
              5.0 * (1 - 1e-6), 5.0 * (1 + 1e-6)):
        inst = (old, 2, np.array([x, 1.0, 1.0, 1.0]), s, 0.25)
        answers = {name: _answer(fn, inst) for name, fn in SOLVERS.items()}
        ref = answers["simple"]
        for name, got in answers.items():
            assert _agrees(got, ref), (x, name, got, ref)
    # the exactly-at-cap point itself must be feasible (tolerance eats the
    # representation error), not a coin flip per solver
    inst = (old, 2, np.array([5.0, 1.0, 1.0, 1.0]), s, 0.25)
    assert _answer(SOLVERS["simple"], inst) != INFEASIBLE
