"""Minimal deterministic stand-in for the slice of Hypothesis this suite uses.

Only importable when the real ``hypothesis`` is absent (tests/conftest.py
inserts this directory into sys.path as a fallback) so a bare
``jax + numpy + pytest`` container can still collect and run the whole
property-test suite.  Install the real package (requirements-dev.txt) for
shrinking, the full strategy library, and adversarial example generation.

Implemented surface:
    @given(**kwargs) / @given(*args)   — runs the test over N drawn examples
    @settings(max_examples=, deadline=) — honoured in either decorator order
    strategies.integers / floats / booleans / sampled_from / lists / tuples
    assume(condition)                   — skips the current example
    HealthCheck                         — accepted and ignored

Examples are drawn from a PRNG seeded by the test's qualified name, so runs
are reproducible; boundary values are always tried first (the cheap half of
what real Hypothesis' shrinking buys).
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

from . import strategies
from .strategies import SearchStrategy

__version__ = "0.0-repro-shim"
__all__ = ["given", "settings", "assume", "strategies", "HealthCheck"]

_DEFAULT_MAX_EXAMPLES = 100


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class HealthCheck:
    """Accepted for API compatibility; the shim has no health checks."""
    all = classmethod(lambda cls: [])
    too_slow = data_too_large = filter_too_much = None


class settings:
    """Both a decorator (``@settings(...)``) and a value object."""

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


def _resolve_max_examples(*fns) -> int:
    for f in fns:
        s = getattr(f, "_shim_settings", None)
        if s is not None:
            return s.max_examples
    return _DEFAULT_MAX_EXAMPLES


def given(*arg_strategies, **kw_strategies):
    for s in list(arg_strategies) + list(kw_strategies.values()):
        if not isinstance(s, SearchStrategy):
            raise TypeError(f"@given expects strategies, got {s!r}")

    def decorate(fn):
        sig_params = list(inspect.signature(fn).parameters)
        pos_names = sig_params[-len(arg_strategies):] if arg_strategies \
            else []
        strat_map = dict(zip(pos_names, arg_strategies))
        strat_map.update(kw_strategies)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = _resolve_max_examples(wrapper, fn)
            seed = zlib.adler32(
                f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            names = list(strat_map)
            boundary_runs = _boundary_examples(strat_map)
            executed = 0
            attempts = 0
            max_attempts = max(n * 10, 50)
            example_iter = iter(boundary_runs)
            while executed < n and attempts < max_attempts:
                attempts += 1
                drawn = next(example_iter, None)
                if drawn is None:
                    drawn = {k: strat_map[k].do_draw(rng) for k in names}
                try:
                    fn(*args, **{**kwargs, **drawn})
                except UnsatisfiedAssumption:
                    continue
                except Exception as e:
                    raise _falsified(fn, drawn, e) from e
                executed += 1
            if executed == 0:
                # mirror real Hypothesis' Unsatisfiable: a test that never
                # ran must not go green
                raise AssertionError(
                    f"{fn.__name__}: assume() rejected all {attempts} "
                    f"generated examples (shim Unsatisfiable)")
            return None

        # pytest must not see the strategy-filled parameters (it would hunt
        # for fixtures of the same name), nor follow __wrapped__ back to fn
        sig = inspect.signature(fn)
        remaining = [p for name, p in sig.parameters.items()
                     if name not in strat_map]
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.__signature__ = sig.replace(parameters=remaining)

        # mimic real Hypothesis' attribute layout: pytest plugins (anyio)
        # introspect obj.hypothesis.inner_test during collection
        class _HypothesisHandle:
            inner_test = staticmethod(fn)

        wrapper.hypothesis = _HypothesisHandle()
        return wrapper

    return decorate


def _boundary_examples(strat_map):
    """Cartesian-free boundary pass: each strategy's extremes, one at a time,
    with every other argument at its own first boundary value."""
    names = list(strat_map)
    base = {k: strat_map[k].boundary()[0] for k in names}
    out = [dict(base)]
    for k in names:
        for v in strat_map[k].boundary()[1:]:
            ex = dict(base)
            ex[k] = v
            out.append(ex)
    return out


def _falsified(fn, drawn, err):
    args = ", ".join(f"{k}={v!r}" for k, v in drawn.items())
    return AssertionError(
        f"Falsifying example (repro shim): {fn.__name__}({args}) "
        f"raised {type(err).__name__}: {err}")
