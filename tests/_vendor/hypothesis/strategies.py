"""Strategy objects for the repro hypothesis shim (see package docstring)."""
from __future__ import annotations

import math
import random
from typing import Any, List, Sequence

__all__ = ["SearchStrategy", "integers", "floats", "booleans",
           "sampled_from", "lists", "tuples", "just"]


class SearchStrategy:
    def do_draw(self, rng: random.Random) -> Any:
        raise NotImplementedError

    def boundary(self) -> List[Any]:
        """Deterministic extreme values, tried before random draws; the
        first element doubles as the strategy's default/base example."""
        return [self.do_draw(random.Random(0))]

    # real Hypothesis composes strategies with .map/.filter; the suite does
    # not use them today, but they are cheap to support
    def map(self, f):
        return _Mapped(self, f)

    def filter(self, pred):
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, inner, f):
        self.inner, self.f = inner, f

    def do_draw(self, rng):
        return self.f(self.inner.do_draw(rng))

    def boundary(self):
        return [self.f(v) for v in self.inner.boundary()]


class _Filtered(SearchStrategy):
    def __init__(self, inner, pred):
        self.inner, self.pred = inner, pred

    def do_draw(self, rng):
        for _ in range(1000):
            v = self.inner.do_draw(rng)
            if self.pred(v):
                return v
        raise ValueError("filter predicate too restrictive (shim)")

    def boundary(self):
        vals = [v for v in self.inner.boundary() if self.pred(v)]
        return vals or [self.do_draw(random.Random(0))]


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int):
        if max_value < min_value:
            raise ValueError("max_value < min_value")
        self.lo, self.hi = int(min_value), int(max_value)

    def do_draw(self, rng):
        return rng.randint(self.lo, self.hi)

    def boundary(self):
        mid = (self.lo + self.hi) // 2
        return sorted({self.lo, self.hi, mid})


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float):
        if not (math.isfinite(min_value) and math.isfinite(max_value)):
            raise ValueError("shim floats() requires finite bounds")
        if max_value < min_value:
            raise ValueError("max_value < min_value")
        self.lo, self.hi = float(min_value), float(max_value)

    def do_draw(self, rng):
        return rng.uniform(self.lo, self.hi)

    def boundary(self):
        out = [self.lo, self.hi, 0.5 * (self.lo + self.hi)]
        return sorted(set(out))


class _Booleans(SearchStrategy):
    def do_draw(self, rng):
        return rng.random() < 0.5

    def boundary(self):
        return [False, True]


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from of empty sequence")

    def do_draw(self, rng):
        return rng.choice(self.elements)

    def boundary(self):
        return self.elements[: min(3, len(self.elements))]


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size=0, max_size=10):
        self.elements = elements
        self.min_size, self.max_size = min_size, max_size

    def do_draw(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.do_draw(rng) for _ in range(n)]

    def boundary(self):
        base = self.elements.boundary()[0]
        out = [[base] * self.min_size]
        if self.max_size != self.min_size:
            out.append([base] * self.max_size)
        return out


class _Tuples(SearchStrategy):
    def __init__(self, *parts: SearchStrategy):
        self.parts = parts

    def do_draw(self, rng):
        return tuple(p.do_draw(rng) for p in self.parts)

    def boundary(self):
        return [tuple(p.boundary()[0] for p in self.parts)]


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def do_draw(self, rng):
        return self.value

    def boundary(self):
        return [self.value]


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return _Integers(min_value, max_value)


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return _Floats(min_value, max_value)


def booleans() -> SearchStrategy:
    return _Booleans()


def sampled_from(elements) -> SearchStrategy:
    return _SampledFrom(elements)


def lists(elements, min_size=0, max_size=10) -> SearchStrategy:
    return _Lists(elements, min_size, max_size)


def tuples(*parts) -> SearchStrategy:
    return _Tuples(*parts)


def just(value) -> SearchStrategy:
    return _Just(value)
