"""Sharding-rule unit tests: every param/cache leaf gets a spec, specs
rank-match their leaves, and the divisibility guarantees hold on the
production meshes (structure-only — no 512-device init needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, get_optimized
from repro.launch.mesh import make_mesh
from repro.launch.shardings import (
    batch_specs, cache_specs, opt_state_specs, param_specs, zero1_spec,
)
from repro.models import init_cache, init_params
from repro.optim import init_opt_state


class FakeMesh:
    """Shape-only stand-in for the 16×16 production mesh."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


POD = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axis_size(mesh, name):
    return mesh.shape[name]


def check_spec_tree(spec_tree, shape_tree, mesh):
    specs = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda s: isinstance(s, P))
    shapes = jax.tree_util.tree_leaves(shape_tree)
    assert len(specs) == len(shapes)
    for sp, leaf in zip(specs, shapes):
        assert isinstance(sp, P)
        assert len(sp) <= len(leaf.shape), (sp, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(sp)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= _axis_size(mesh, a)
            assert dim % total == 0, (sp, leaf.shape, dim, total)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("variant", ["base", "opt"])
def test_param_and_opt_specs_divisible(arch, variant):
    cfg = get_config(arch) if variant == "base" else get_optimized(arch)
    params_s = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    for mesh in (POD, MULTI):
        pspecs = param_specs(cfg, mesh, params_s)
        check_spec_tree(pspecs, params_s, mesh)
        opt_s = jax.eval_shape(init_opt_state, params_s)
        ospecs = opt_state_specs(pspecs, params_s, mesh)
        check_spec_tree(ospecs["master"], params_s, mesh)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "whisper-large-v3",
                                  "mixtral-8x7b", "falcon-mamba-7b",
                                  "recurrentgemma-9b"])
def test_cache_specs_divisible_and_bounded(arch):
    cfg = get_config(arch)
    from repro.configs import decode_cache_len
    shape = SHAPES["decode_32k"]
    cache_s = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch,
                           decode_cache_len(cfg, shape)))
    for mesh in (POD, MULTI):
        cspecs = cache_specs(cfg, mesh, cache_s)
        check_spec_tree(cspecs, cache_s, mesh)
        # per-device KV bytes must fit a v5e (16 GB) with headroom
        total = 0
        for sp, leaf in zip(
                jax.tree_util.tree_leaves(
                    cspecs, is_leaf=lambda s: isinstance(s, P)),
                jax.tree_util.tree_leaves(cache_s)):
            shards = 1
            for entry in tuple(sp):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                for a in axes:
                    shards *= _axis_size(mesh, a)
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // shards
        assert total < 8e9, f"{arch}: {total/1e9:.1f} GB cache per device"


def test_zero1_adds_data_axis_on_divisible_dim():
    spec = zero1_spec(P(None, "model"), (4096, 16 * 128), POD)
    assert spec == P("data", "model") or spec[0] in ("data", ("data",))
    # no divisible dim -> unchanged
    spec2 = zero1_spec(P(None,), (4097,), POD)
    assert spec2 == P(None)


def test_batch_specs_replicate_unshardable():
    cfg = get_config("falcon-mamba-7b")
    b1 = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)}
    sp = batch_specs(cfg, POD, b1)
    assert sp["tokens"] == P(None, None)    # B=1: replicated
    b128 = {"tokens": jax.ShapeDtypeStruct((128, 8), jnp.int32)}
    sp = batch_specs(cfg, POD, b128)
    assert tuple(sp["tokens"])[0] in ("data", ("data",))
