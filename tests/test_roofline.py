"""Loop-aware HLO analyzer validation (the roofline's foundation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.roofline.hlo import analyze, parse_computations
from repro.roofline.terms import model_flops
from repro.models.config import SHAPES


def _costs(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze(txt, 1)


def test_xla_cost_analysis_counts_scan_body_once():
    """The empirical fact that motivates the custom analyzer."""
    def f(x, w):
        return lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    ca = jax.jit(f).lower(x, w).compile().cost_analysis()
    if isinstance(ca, list):  # jax<=0.4.x: one dict per addressable device
        ca = ca[0]
    assert ca["flops"] == pytest.approx(2 * 128 * 256 * 256)  # 1/10th!


def test_scan_flops_exact():
    def f(x, w):
        return lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    c = _costs(f, x, w)
    assert c.dot_flops == pytest.approx(10 * 2 * 128 * 256 * 256)
    assert 10 in c.while_trips


def test_nested_scan_flops_exact():
    def f(x, w):
        def outer(c, wi):
            c2, _ = lax.scan(lambda cc, _: (cc @ wi, None), c,
                             jnp.arange(5))
            return c2, None
        return lax.scan(outer, x, w)[0]
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    c = _costs(f, x, w)
    assert c.dot_flops == pytest.approx(50 * 2 * 128 * 256 * 256)
    assert sorted(c.while_trips) == [5, 10]


def test_dus_counts_slice_not_buffer():
    """In-place dynamic-update-slice must charge the slice, not the cache."""
    def f(cache, x):
        def body(c, xi):
            c = lax.dynamic_update_slice_in_dim(c, xi[None], 0, axis=0)
            return c, None
        return lax.scan(body, cache, x)[0]
    cache = jax.ShapeDtypeStruct((1024, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 256), jnp.float32)
    c = _costs(f, cache, x)
    # 8 iterations × slice (256 f32) — far below 8 × full cache
    assert c.hbm_bytes < 8 * 1024 * 256 * 4


def test_collective_bytes_allreduce():
    import subprocess, sys, json
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.roofline.hlo import analyze
from repro.compat import shard_map
mesh = jax.make_mesh((8,), ("d",))
f = shard_map(lambda x: jax.lax.psum(x, "d"), mesh=mesh,
              in_specs=P(None), out_specs=P(None))
txt = jax.jit(f).lower(jax.ShapeDtypeStruct((1024,), jnp.float32)
                       ).compile().as_text()
c = analyze(txt, 8)
print(json.dumps({"cb": c.collective_bytes,
                  "counts": c.collective_counts}))
"""
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-1000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # ring all-reduce: 2 · bytes · (n-1)/n
    assert rec["cb"] == pytest.approx(2 * 1024 * 4 * 7 / 8)
    assert rec["counts"] == {"all-reduce": 1}


def test_model_flops_sane_across_archs():
    from repro.configs import ARCH_IDS, get_config
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            mf = model_flops(cfg, shape)
            assert mf > 0
            if shape.kind == "train":
                # 6·N·D dominates; sanity band around it
                approx = 6.0 * cfg.active_params() * shape.global_batch * \
                    shape.seq_len
                assert 0.3 * approx < mf < 12 * approx, (arch, shape.name)


def test_decode_useful_ratio_near_one_end_to_end():
    """Full pipeline check: a tiny dense decode step's analyzer flops match
    the analytic 2·N·B within tolerance (no remat/masking in decode)."""
    from repro.configs import get_smoke
    from repro.models import decode_step, init_cache, init_params
    cfg = get_smoke("qwen2.5-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 4
    cache = init_cache(cfg, B, 64)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.full((B,), 3, jnp.int32)
    txt = jax.jit(lambda p, c, t, q: decode_step(p, cfg, t, q, c)).lower(
        params, cache, tok, pos).compile().as_text()
    c = analyze(txt, 1)
    emb = cfg.vocab_size * cfg.d_model
    n_mm = cfg.n_params() - emb
    expect = 2.0 * n_mm * B
    assert 0.7 * expect < c.dot_flops < 1.6 * expect
