"""Attention schedule equivalence: masked / folded / banded all compute the
same function (the folded schedule re-orders block pairs; banded restricts
to the window) — swept over shapes, windows and GQA ratios."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import attention_reference, blocked_attention

KEY = jax.random.PRNGKey(0)


def mk(B, S, H, Hkv, hd, dtype=jnp.float32):
    q = jax.random.normal(KEY, (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,Hkv,hd", [
    (2, 256, 4, 2, 32), (1, 512, 8, 1, 64), (2, 128, 6, 6, 16),
])
def test_folded_equals_masked_equals_reference(B, S, H, Hkv, hd):
    q, k, v = mk(B, S, H, Hkv, hd)
    ref = attention_reference(q, k, v, causal=True)
    masked = blocked_attention(q, k, v, causal=True, q_block=64,
                               kv_block=64, schedule="masked")
    folded = blocked_attention(q, k, v, causal=True, q_block=64,
                               kv_block=64, schedule="folded")
    np.testing.assert_allclose(np.asarray(masked), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(folded), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_banded_equals_reference(window):
    q, k, v = mk(2, 256, 4, 2, 32)
    ref = attention_reference(q, k, v, causal=True, window=window)
    banded = blocked_attention(q, k, v, causal=True, window=window,
                               q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_folded_odd_blocks_falls_back():
    """nq odd: folded silently uses the masked path (still correct)."""
    q, k, v = mk(1, 192, 4, 2, 32)
    ref = attention_reference(q, k, v, causal=True)
    out = blocked_attention(q, k, v, causal=True, q_block=64, kv_block=64,
                            schedule="folded")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


def test_cross_attention_unequal_lengths():
    q, _, _ = mk(2, 128, 4, 4, 32)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 320, 4, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 320, 4, 32))
    ref = attention_reference(q, k, v, causal=False)
    out = blocked_attention(q, k, v, causal=False, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)
