"""SSM correctness: paper Table 1 exact reproduction + DP-vs-oracle
equivalence (hypothesis) + load-balance/cost invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Assignment,
    Infeasible,
    adhoc,
    brute_force,
    greedy_sequence,
    greedy_trim,
    migration_cost,
    oms,
    satisfies_balance,
    simple_ssm,
    ssm,
)


# ---------------------------------------------------------------------------
# Paper Table 1 (§2.2): exact numbers.
# ---------------------------------------------------------------------------

W20 = np.ones(20)
S20 = np.ones(20)


def test_table1_costs_of_papers_strategies():
    """Verify the paper's Table 1 arithmetic under the contiguous-interval
    model.

    The paper's "9,9,2 at cost 4" step ("two tasks from N1 to N2, two from N1
    to N3") reads, contiguously, as N1=[0,9), N2=[11,20) (9 tasks: 7 kept + 2
    received), N3=[9,11).  The "8,7,5 at cost 5" alternative is N1=[0,8),
    N2=[13,20) kept intact, N3=[8,13).  The *second*-step numbers in Table 1
    (6,6,2,6 / 6,6,4,4) are set-based and not all realizable as contiguous
    intervals; we assert the paper's headline instead: the greedy-optimal
    first step is beatable over two steps, and OMS finds a plan with total
    cost <= the paper's alternative (9)."""
    t1 = Assignment.from_boundaries(20, [0, 13, 20])              # 13, 7
    t2a = Assignment(20, ((0, 9), (11, 20), (9, 11)))             # 9, 9, 2
    assert migration_cost(t1, t2a, S20) == 4
    assert satisfies_balance(t2a, W20, 3, 0.4)
    t2b = Assignment(20, ((0, 8), (13, 20), (8, 13)))             # 8, 7, 5
    assert migration_cost(t1, t2b, S20) == 5
    assert satisfies_balance(t2b, W20, 3, 0.4)
    res = oms(t1, [(3, 0.4), (4, 0.4)], W20, S20)
    assert res.total_cost <= 9.0


def test_table1_ssm_is_single_step_optimal():
    t1 = Assignment.from_boundaries(20, [0, 13, 20])
    p2 = ssm(t1, 3, W20, S20, 0.4)
    assert p2.cost == 4.0                       # paper: cost 4 at t2
    assert satisfies_balance(p2.new, W20, 3, 0.4)
    bf = brute_force(t1, 3, W20, S20, 0.4)
    assert bf.cost == 4.0


def test_table1_sequence_beats_greedy():
    """Sequence-optimal <= greedy single-step chain, and both beat the
    paper's 10 (greedy) via optimal tie-breaking; the true optimum is 6."""
    t1 = Assignment.from_boundaries(20, [0, 13, 20])
    seq = oms(t1, [(3, 0.4), (4, 0.4)], W20, S20)
    greedy = greedy_sequence(t1, [(3, 0.4), (4, 0.4)], W20, S20)
    assert seq.total_cost <= greedy.total_cost
    assert seq.total_cost == 6.0
    # the paper's specific greedy tie-break (contiguous 9,9,2) costs 10:
    t2a = Assignment(20, ((0, 9), (9, 18), (18, 20)))
    p3 = ssm(t2a, 4, W20, S20, 0.4)
    assert migration_cost(t1, t2a, S20) + p3.cost >= 9.0


# ---------------------------------------------------------------------------
# Oracle equivalence (hypothesis property tests)
# ---------------------------------------------------------------------------

def _rand_instance(rng, m, n_old):
    cuts = (
        np.sort(rng.choice(np.arange(1, m), size=n_old - 1, replace=False))
        if n_old > 1 else np.array([], dtype=int)
    )
    old = Assignment.from_boundaries(m, [0, *cuts.tolist(), m])
    w = rng.uniform(0.2, 2.0, m)
    s = rng.uniform(0.1, 3.0, m)
    return old, w, s


@given(m=st.integers(4, 12), n_old=st.integers(1, 4), n_new=st.integers(1, 5),
       tau=st.floats(0.1, 2.0), seed=st.integers(0, 99_999))
@settings(max_examples=120, deadline=None)
def test_ssm_equals_bruteforce(m, n_old, n_new, tau, seed):
    rng = np.random.default_rng(seed)
    n_old = min(n_old, m - 1)
    old, w, s = _rand_instance(rng, m, n_old)
    try:
        bf = brute_force(old, n_new, w, s, tau)
    except Infeasible:
        with pytest.raises(Infeasible):
            ssm(old, n_new, w, s, tau)
        return
    fast = ssm(old, n_new, w, s, tau)
    assert fast.gain == pytest.approx(bf.gain, rel=1e-9, abs=1e-9)
    assert satisfies_balance(fast.new, w, n_new, tau)
    fast.new.validate()


@given(m=st.integers(5, 20), n_old=st.integers(1, 6), n_new=st.integers(1, 6),
       tau=st.floats(0.1, 2.0), seed=st.integers(0, 99_999))
@settings(max_examples=80, deadline=None)
def test_ssm_equals_simple_ssm(m, n_old, n_new, tau, seed):
    rng = np.random.default_rng(seed)
    n_old = min(n_old, m - 1)
    old, w, s = _rand_instance(rng, m, n_old)
    try:
        slow = simple_ssm(old, n_new, w, s, tau)
    except Infeasible:
        with pytest.raises(Infeasible):
            ssm(old, n_new, w, s, tau)
        return
    fast = ssm(old, n_new, w, s, tau)
    assert fast.gain == pytest.approx(slow.gain, rel=1e-9, abs=1e-9)


@given(m=st.integers(8, 48), n_old=st.integers(2, 10),
       n_new=st.integers(2, 10), tau=st.floats(0.2, 1.5),
       seed=st.integers(0, 99_999))
@settings(max_examples=60, deadline=None)
def test_ssm_invariants_medium(m, n_old, n_new, tau, seed):
    """At sizes beyond the oracles: structural invariants only."""
    rng = np.random.default_rng(seed)
    n_old = min(n_old, m - 1)
    old, w, s = _rand_instance(rng, m, n_old)
    try:
        plan = ssm(old, n_new, w, s, tau)
    except Infeasible:
        return
    plan.new.validate()
    assert satisfies_balance(plan.new, w, n_new, tau)
    assert plan.cost >= -1e-9
    assert plan.gain + plan.cost == pytest.approx(s.sum())
    assert plan.n_active <= n_new
    # no *feasible* strategy can beat SSM.  adhoc ignores the balance cap by
    # design (it models Storm's default scheduler), so only compare when its
    # output happens to satisfy the cap.
    for base in (adhoc, greedy_trim):
        try:
            b = base(old, n_new, w, s, tau)
        except Infeasible:
            continue
        if satisfies_balance(b.new, w, n_new, tau):
            assert plan.cost <= b.cost + 1e-9


def test_grow_shrink_roundtrip_costs():
    """Growing then shrinking back costs at least the state the new node
    received (it must leave again)."""
    rng = np.random.default_rng(7)
    m = 32
    old, w, s = _rand_instance(rng, m, 4)
    up = ssm(old, 6, w, s, 0.5)
    down = ssm(up.new, 4, w, s, 0.5)
    assert up.cost > 0 and down.cost > 0
    assert satisfies_balance(down.new, w, 4, 0.5)


def test_rebalance_same_n():
    """n'==n rebalancing (paper: skew response) fixes a violated cap."""
    m = 16
    w = np.ones(m)
    w[:4] = 10.0                    # hot head
    s = np.ones(m)
    old = Assignment.from_boundaries(m, [0, 4, 8, 16])  # node0 load 40
    assert not satisfies_balance(old, w, 3, 0.5)
    plan = ssm(old, 3, w, s, 0.5)
    assert satisfies_balance(plan.new, w, 3, 0.5)
    assert plan.cost > 0


def test_infeasible_single_fat_task():
    m = 4
    w = np.array([100.0, 1.0, 1.0, 1.0])
    old = Assignment.from_boundaries(m, [0, 2, 4])
    with pytest.raises(Infeasible):
        ssm(old, 4, w, np.ones(m), 0.1)
