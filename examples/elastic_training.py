"""Elastic training with checkpoint-restart and optimizer-state migration.

    PYTHONPATH=src python examples/elastic_training.py

Trains a reduced qwen3-family model (~1M params smoke config; pass --big
for a ~100M-param olmo-1b config if you have the cycles) with:

* deterministic restart-safe data (same stream after resume),
* a mid-run SIMULATED preemption: checkpoint, drop the process state,
  restore — loss curve continues exactly,
* bucketed optimizer-state migration: the ZeRO shards are m buckets; when
  the data-parallel group "scales" 4 → 6, SSM plans the minimal shard
  movement (vs ad-hoc resharding that reshuffles nearly everything).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import Assignment, ElasticPlanner, TauSchedule, adhoc, ssm
from repro.data import SyntheticLM
from repro.launch.train import load_train_ckpt, save_train_ckpt
from repro.models import init_params, loss_fn
from repro.optim import OptConfig, adamw_update, init_opt_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_elastic_train")
    args = ap.parse_args(argv)

    cfg = get_smoke("olmo-1b" if args.big else "qwen3-8b")
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt_state = init_opt_state(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch))(params)
        p2, o2, met = adamw_update(grads, opt_state, params, opt_cfg)
        met["loss"] = loss
        return p2, o2, met

    half = args.steps // 2
    losses = []
    for step in range(half):
        params, opt_state, met = step_fn(params, opt_state,
                                         ds.batch_at(step))
        losses.append(float(met["loss"]))
    print(f"step {half-1}: loss {losses[-1]:.4f} — checkpoint + preempt")
    from pathlib import Path
    save_train_ckpt(Path(args.ckpt), half, params, opt_state)

    # --- simulated preemption: fresh state, restore ------------------------
    params2 = init_params(cfg, jax.random.PRNGKey(999))     # junk
    opt2 = init_opt_state(params2)
    start, params2, opt2 = load_train_ckpt(
        Path(args.ckpt), {"params": params2, "opt": opt2})
    params2 = jax.tree_util.tree_map(jnp.asarray, params2)
    opt2 = jax.tree_util.tree_map(jnp.asarray, opt2)
    print(f"restored at step {start}; resuming")
    for step in range(start, args.steps):
        params2, opt2, met = step_fn(params2, opt2, ds.batch_at(step))
        losses.append(float(met["loss"]))
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}) — "
          f"{'DECREASED' if losses[-1] < losses[0] else 'FLAT'}")
    assert losses[-1] < losses[0]

    # --- optimizer-shard migration on elastic resize ------------------------
    # ZeRO-1 shards as m=32 buckets over 4 DP nodes; scale to 6.
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params2))
    m = 32
    shard_bytes = np.full(m, n_params * 12.0 / m)   # f32 master+m+v
    w = np.ones(m)
    old = Assignment.from_boundaries(m, [0, 8, 16, 24, 32])
    opt_plan = ssm(old, 6, w, shard_bytes, 0.2)
    naive = adhoc(old, 6, w, shard_bytes, 0.2)
    print(f"DP resize 4→6: SSM moves {opt_plan.cost/1e6:.1f} MB of "
          f"optimizer state; ad-hoc resharding moves {naive.cost/1e6:.1f} "
          f"MB ({naive.cost/max(opt_plan.cost,1e-9):.1f}×)")
    assert opt_plan.cost <= naive.cost
    print("OK")


if __name__ == "__main__":
    main()
