"""END-TO-END DRIVER: serve a small LM with batched requests while the
serving fleet scales elastically and survives a node failure.

    PYTHONPATH=src python examples/elastic_serving.py

A qwen2.5-3b-family (reduced) model serves 24 concurrent requests.
Requests hash into 24 KV buckets; each node owns a contiguous bucket
interval (the paper's routing design).  Mid-decode we
  (a) scale 2 → 4 nodes (SSM plans minimal KV movement, the batched_fluid
      executor ships it in conflict-free matching rounds),
  (b) kill node 0 (failure recovery: survivors keep their KV in place,
      the lost buckets' cost is charged to checkpoint restore),
and decoding continues throughout — generated tokens are bit-identical to
an uninterrupted run (state migration is transparent to the model).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import ElasticPlanner, TauSchedule
from repro.models import decode_step, init_cache, init_params, prefill
from repro.runtime import (
    BucketedState, ElasticController, MigrationExecutor, SimBackend, route,
)


def run(events: bool):
    cfg = get_smoke("qwen2.5-3b")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, P, G, m = 24, 16, 24, 24
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size, jnp.int32)

    cache = init_cache(cfg, B, P + G + 1)
    logits, cache = prefill(params, cfg, {"tokens": prompts}, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    req_bucket = route(np.arange(B), m)
    per_req_kv = sum(int(np.prod(v.shape[1:])) * v.dtype.itemsize
                     for v in jax.tree_util.tree_leaves(cache))
    kv_bytes = np.array([per_req_kv * (req_bucket == j).sum()
                         for j in range(m)], float)
    op_state = BucketedState(
        [{"kv": np.zeros(max(int(kv_bytes[j] // 8), 1))} for j in range(m)])
    ctl = ElasticController(
        m, 2,
        planner=ElasticPlanner(policy="ssm",
                               tau=TauSchedule(base=1.2, grow=0.2)),
        executor=MigrationExecutor(backend=SimBackend(bw_bytes_per_s=2e9),
                                   mode="batched_fluid", fluid_batch=4))
    w = np.bincount(req_bucket, minlength=m).astype(float) + 1e-9

    step_fn = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, t, pos, c))
    toks = [tok]
    lat = []
    for g in range(G):
        if events and g == 6:
            plan, rep = ctl.scale(4, w, op_state)
            print(f"  step {g}: scale 2→4 — moved "
                  f"{rep.bytes_moved/1e3:.0f} KB of KV in {rep.phases} "
                  f"matching rounds, {rep.duration_s*1e3:.2f} ms "
                  f"(simulated ICI)")
        if events and g == 14:
            plan, rep = ctl.recover({0}, w, op_state)
            ck = ctl.events[-1].details["checkpoint_bytes"]
            print(f"  step {g}: node 0 FAILED — survivors kept "
                  f"{(1 - rep.bytes_moved/max(kv_bytes.sum(),1)) * 100:.0f}% "
                  f"of KV in place; {ck/1e3:.0f} KB restored from ckpt; "
                  f"now {ctl.n_nodes} nodes")
        t0 = time.time()
        pos = jnp.full((B,), P + g, jnp.int32)
        logits, cache = step_fn(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(tok)
        lat.append(time.time() - t0)
    return jnp.concatenate(toks, axis=1), lat, ctl


def main():
    print("reference run (no elastic events)...")
    ref, _, _ = run(events=False)
    print("elastic run (scale-up @6, node failure @14)...")
    got, lat, ctl = run(events=True)
    assert (np.asarray(ref) == np.asarray(got)).all(), \
        "generation must be identical across elastic events"
    print(f"decode p50 {np.median(lat)*1e3:.0f} ms; "
          f"events: {[(e.kind, e.n_before, e.n_after) for e in ctl.events]}")
    print("OK — tokens bit-identical with and without elastic events")


if __name__ == "__main__":
    main()
