"""Quickstart: the paper's running example (word count) with optimal
operator-state migration.

    PYTHONPATH=src python examples/quickstart.py

A word stream flows into a stateful counting operator split into m=32 hash
buckets across 2 nodes.  We burst-load it, scale to 5 nodes, compare SSM's
migration bytes against the ad-hoc (Storm-default) strategy, shrink back on
the quiet period, and verify not a single count was lost.  A final section
replays the same elastic events on the vectorized serving simulator to show
what each migration strategy (kill_restart / live / progressive / fluid /
batched_fluid) costs in response-time spike.
"""
import numpy as np

from repro.core import ElasticPlanner, TauSchedule, adhoc
from repro.runtime import (
    ElasticWordCount, MigrationExecutor, SimBackend, SimConfig,
    VectorizedServingSim,
)


def main():
    rng = np.random.default_rng(0)
    app = ElasticWordCount(
        m=32, n_nodes=2,
        planner=ElasticPlanner(policy="ssm",
                               tau=TauSchedule(base=1.2, grow=0.2)),
        executor=MigrationExecutor(backend=SimBackend(bw_bytes_per_s=1e9),
                                   mode="live"))

    # 1) steady stream
    words = rng.zipf(1.3, 20_000) % 5_000
    app.ingest(words)
    total_state = app.state.bucket_bytes().sum()
    print(f"ingested {len(words)} words; operator state "
          f"{total_state/1e3:.1f} KB across {app.m} buckets on 2 nodes")

    # 2) burst => scale 2 -> 5
    burst = np.concatenate([words, rng.integers(0, 50, 30_000)])
    app.ingest(burst)
    before = app.totals()
    s = app.state.bucket_bytes()
    w = app.work + 1e-9
    naive = adhoc(app.assign, 5, w, s, 0.2)
    plan, rep = app.scale(5)
    print(f"scale 2→5: SSM moved {rep.bytes_moved/1e3:.1f} KB "
          f"in {rep.phases} phases ({rep.duration_s*1e3:.2f} ms); "
          f"ad-hoc would move {naive.cost/1e3:.1f} KB "
          f"({naive.cost/max(rep.bytes_moved,1e-9):.1f}× more)")
    assert app.totals() == before, "counts must survive the migration"

    # 3) quiet period => scale back 5 -> 3
    plan2, rep2 = app.scale(3)
    print(f"scale 5→3: moved {rep2.bytes_moved/1e3:.1f} KB "
          f"in {rep2.phases} phases")
    assert app.totals() == before

    top = sorted(before.items(), key=lambda kv: -kv[1])[:5]
    print("top-5 words:", top)
    print("OK — zero counts lost across two elastic events")

    # 4) what would each migration strategy have cost in latency?  Replay a
    # scale 2→5 event on the vectorized serving simulator (same §5
    # semantics, array engine — scales to 10k+ buckets, see
    # benchmarks/fig12) with the word-count app's state sizes and a steady
    # tuple rate.
    T, m = 12, app.m
    w_trace = np.tile(rng.uniform(50.0, 150.0, m), (T, 1))
    s_trace = np.tile(app.state.bucket_bytes(), (T, 1))
    trace = np.array([2] * 4 + [5] * (T - 4))
    print("\nstrategy comparison on the serving simulator (scale 2→5):")
    for mode in ("kill_restart", "live", "progressive", "fluid",
                 "batched_fluid"):
        sv = VectorizedServingSim(
            m, SimConfig(interval_s=10.0, bw_bytes_per_s=1e4),
            ElasticPlanner(policy="ssm"), mode=mode, tau=0.6,
            fluid_batch=4 if mode == "batched_fluid" else 1)
        mets = sv.run(w_trace, s_trace, trace)
        spike = max(x.max_response_s for x in mets)
        dur = sum(x.migration_duration_s for x in mets)
        print(f"  {mode:13s} worst response {spike*1e3:9.1f} ms, "
              f"migrating for {dur:5.2f} s")


if __name__ == "__main__":
    main()
