"""MoE expert rebalancing as operator-state migration.

    PYTHONPATH=src python examples/moe_rebalance.py

Experts of an MoE layer are the paper's "tasks": workload w_j = routed
token counts (from the real router of a reduced phi3.5-family model),
state |s_j| = expert weight bytes.  When routing skews (a hot topic), the
expert-to-device assignment rebalances with SSM — moving the fewest expert
bytes that restores balance — vs the ad-hoc equal-count reassignment.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import Assignment, adhoc, satisfies_balance, ssm
from repro.models import init_params
from repro.models.layers import moe_apply


def main():
    cfg = get_smoke("phi3.5-moe-42b-a6.6b").replace(n_experts=16, top_k=2)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    moe_p = params["blocks"][0]["moe"]
    layer0 = jax.tree_util.tree_map(lambda x: x[0], moe_p)

    # real routing decisions over a token batch
    x = jax.random.normal(key, (8, 128, cfg.d_model), jnp.bfloat16)
    _, logits = moe_apply(x, layer0, cfg)
    top = jax.lax.top_k(logits, cfg.top_k)[1].reshape(-1)
    counts = np.bincount(np.asarray(top), minlength=cfg.n_experts).astype(
        float)
    # inject a hot expert (bursty topic)
    counts[3] *= 5.0
    E = cfg.n_experts
    per_expert_bytes = float(sum(
        np.prod(layer0[k].shape[1:]) * 2 for k in ("w_gate", "w_up",
                                                   "w_down")))
    s = np.full(E, per_expert_bytes)

    old = Assignment.from_boundaries(E, [0, 4, 8, 12, 16])  # 4 devices
    print(f"expert load (tokens): {counts.astype(int)}")
    print(f"balanced? {satisfies_balance(old, counts, 4, 0.4)}")
    plan = ssm(old, 4, counts, s, 0.4)
    naive = adhoc(old, 4, counts, s, 0.4)  # equal expert count: no rebalance
    print(f"SSM rebalance: moves {plan.cost/1e3:.0f} KB of expert weights "
          f"({plan.cost/per_expert_bytes:.0f} experts) and restores "
          f"balance; ad-hoc keeps the equal-count split (0 bytes) but "
          f"stays overloaded: "
          f"balanced={satisfies_balance(naive.new, counts, 4, 0.4)}")
    assert satisfies_balance(plan.new, counts, 4, 0.4)
    assert not satisfies_balance(naive.new, counts, 4, 0.4)
    loads = plan.new.node_loads(counts)
    print(f"post-migration device loads: {loads.astype(int)} "
          f"(cap {(1.4 * counts.sum() / 4):.0f})")
    print("OK")


if __name__ == "__main__":
    main()
