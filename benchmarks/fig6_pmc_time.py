"""Paper Fig. 6: τ vs PMC pre-computation time.

The paper runs PMC on an 8-machine Spark cluster for hundreds of minutes at
m=64.  Here the same value iteration runs single-host at m=24/grid=2, plus
two beyond-paper accelerations measured against it:

* grid coarsening (grid=1 exact vs grid=2): table-size reduction with a
  measured optimality loss (reported as cost delta);
* the batched interval_gain path (kernels/interval_gain.py, numpy chunked
  DP here; the Pallas kernel is the TPU version of the same loop).
"""
import time

import numpy as np

from repro.core import PartitionTable, pmc
from .common import (
    M_MTM, N_HI_MTM, N_LO_MTM, build_pmc, emit, run_policy_over_trace,
    stream,
)

TAUS = (0.4, 0.8, 1.2)


def main():
    w, s, trace = stream(M_MTM, N_LO_MTM, N_HI_MTM, zipf_a=0.5,
                          burst_mult=3.0)
    rows = []
    for tau in TAUS:
        res2, t2 = build_pmc(w, s, trace, tau, grid=2)
        r2 = run_policy_over_trace("mtm", w, s, trace, tau, pmc_result=res2)
        # exact table (grid=1) where it stays tractable
        t1 = cost1 = float("nan")
        try:
            res1, t1 = build_pmc(w, s, trace, tau, grid=1)
            r1 = run_policy_over_trace("mtm", w, s, trace, tau,
                                       pmc_result=res1)
            cost1 = r1["avg_cost_pct"]
        except MemoryError:
            pass
        rows.append((tau, res2.table.Q, round(t2, 2),
                     round(r2["avg_cost_pct"], 2),
                     round(t1, 2), round(cost1, 2),
                     res2.iterations))
    out = emit(rows, ("tau", "partitions_grid2", "pmc_s_grid2",
                      "mtm_cost_pct_grid2", "pmc_s_exact",
                      "mtm_cost_pct_exact", "vi_iterations"))
    # PMC time grows with tau (larger feasible space), as in the paper
    assert out[-1]["pmc_s_grid2"] >= out[0]["pmc_s_grid2"] * 0.5
    return out


if __name__ == "__main__":
    main()
