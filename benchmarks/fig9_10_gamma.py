"""Paper Figs. 9+10: discount factor γ vs MTM migration cost (Fig. 9, ↓
with γ) and vs PMC pre-computation time (Fig. 10, ↑ with γ — more value-
iteration sweeps to converge)."""
import numpy as np

from .common import (
    M_SMALL, N_HI_SMALL, N_LO_SMALL, build_pmc, emit,
    run_policy_over_trace, stream,
)

GAMMAS = (0.0, 0.4, 0.8, 0.95)


def main():
    w, s, trace = stream(M_SMALL, N_LO_SMALL, N_HI_SMALL, zipf_a=0.5,
                         burst_mult=3.0)
    rows = []
    for g in GAMMAS:
        pmc_res, t_pre = build_pmc(w, s, trace, tau=0.8, gamma=g,
                                   grid=1, limit_per_k=None)
        res = run_policy_over_trace("mtm", w, s, trace, tau=0.8,
                                    pmc_result=pmc_res)
        rows.append((g, round(res["avg_cost_pct"], 2), round(t_pre, 2),
                     pmc_res.iterations))
    out = emit(rows, ("gamma", "mtm_cost_pct", "pmc_s", "vi_iterations"))
    # gamma=0 reduces to single-step; larger gamma must not cost more
    assert out[0]["mtm_cost_pct"] >= out[-1]["mtm_cost_pct"] - 1e-9
    assert out[-1]["vi_iterations"] >= out[0]["vi_iterations"]
    return out


if __name__ == "__main__":
    main()
