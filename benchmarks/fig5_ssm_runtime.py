"""Paper Fig. 5: τ vs SSM planning time (the online path must be fast —
the paper reports <2 ms at m=64), plus the backend scaling study:
plan time vs m for the numpy (paper Fig. 14 verbatim) and jit
(core/ssm_jit lax.scan) backends, persisted to BENCH_ssm.json.

Default mode keeps the sweep small enough for the full benchmark drive;
``SSM_BENCH_FULL=1`` adds the m=10,000 numpy-vs-jit headline comparison
(numpy takes ~390 s there — the jit target is ≥50× faster) and an
m=100,000 jit-only plan."""
import os
import time

import numpy as np

from repro.core.intervals import Assignment
from repro.core.ssm import ssm

from .common import (
    M_FULL, N_LO, N_HI, emit, run_policy_over_trace, stream,
    write_bench_json,
)

TAUS = (0.4, 0.6, 0.8, 1.2, 1.6)
M_SWEEP = (256, 512, 1024)
M_HEADLINE = 10_000
M_JIT_ONLY = 100_000


def scaling_instance(m: int, n_old: int = 12, n_new: int = 16,
                     tau: float = 0.4, seed: int = 0):
    """The fixed benchmark instance family (same generator at every m, so
    timings are comparable across runs and sessions)."""
    rng = np.random.default_rng(seed)
    bs = np.linspace(0, m, n_old + 1).round().astype(int)
    old = Assignment(m, tuple((int(bs[i]), int(bs[i + 1]))
                              for i in range(n_old)))
    w = rng.uniform(0.2, 2.0, size=m)
    s = rng.uniform(0.1, 3.0, size=m)
    return old, n_new, w, s, tau


def time_backend(backend: str, m: int, repeats: int = 2):
    """(first_s, steady_s, gain) — first call includes jit compilation."""
    inst = scaling_instance(m)
    t0 = time.perf_counter()
    plan = ssm(*inst, backend=backend)
    first = time.perf_counter() - t0
    steady = first
    for _ in range(repeats - 1):
        t0 = time.perf_counter()
        plan = ssm(*inst, backend=backend)
        steady = time.perf_counter() - t0
    return first, steady, float(plan.gain)


def main():
    # paper figure: τ sweep at protocol scale (m=64, python-loop budget)
    w, s, trace = stream(M_FULL, N_LO, N_HI)
    rows = []
    for tau in TAUS:
        res = run_policy_over_trace("ssm", w, s, trace, tau)
        rows.append((tau, round(res["avg_plan_ms"], 3), res["migrations"]))
    out = emit(rows, ("tau", "ssm_plan_ms", "migrations"))
    assert all(r["ssm_plan_ms"] < 1000.0 for r in out)

    # backend scaling: plan time vs m, both backends on one instance family
    full = os.environ.get("SSM_BENCH_FULL", "") == "1"
    records = []
    for m in M_SWEEP + ((M_HEADLINE,) if full else ()):
        gains = {}
        for backend in ("numpy", "jit"):
            first, steady, gain = time_backend(backend, m)
            gains[backend] = gain
            records.append({"m": m, "backend": backend,
                            "first_s": round(first, 4),
                            "steady_s": round(steady, 4),
                            "gain": gain})
        assert abs(gains["numpy"] - gains["jit"]) <= \
            1e-9 * max(1.0, abs(gains["numpy"])), (m, gains)
    if full:
        first, steady, gain = time_backend("jit", M_JIT_ONLY, repeats=1)
        records.append({"m": M_JIT_ONLY, "backend": "jit",
                        "first_s": round(first, 4),
                        "steady_s": round(steady, 4), "gain": gain})
        np_10k = next(r["steady_s"] for r in records
                      if r["m"] == M_HEADLINE and r["backend"] == "numpy")
        jit_10k = next(r["steady_s"] for r in records
                       if r["m"] == M_HEADLINE and r["backend"] == "jit")
        assert jit_10k * 50 <= np_10k, (np_10k, jit_10k)
    emit([(r["m"], r["backend"], r["first_s"], r["steady_s"])
          for r in records],
         ("m", "backend", "first_s", "steady_s"))
    write_bench_json("ssm", {
        "mode": "full" if full else "fast",
        "instance": {"n_old": 12, "n_new": 16, "tau": 0.4, "seed": 0,
                     "w": "U(0.2,2.0)", "s": "U(0.1,3.0)"},
        "plan_time_vs_m": records,
        "tau_sweep_m64": out,
    })
    return out


if __name__ == "__main__":
    main()
