"""Paper Fig. 5: τ vs SSM planning time (the online path must be fast —
the paper reports <2 ms at m=64)."""
import numpy as np

from .common import M_FULL, N_HI, N_LO, emit, run_policy_over_trace, stream

TAUS = (0.4, 0.6, 0.8, 1.2, 1.6)


def main():
    w, s, trace = stream(M_FULL, N_LO, N_HI)
    rows = []
    for tau in TAUS:
        res = run_policy_over_trace("ssm", w, s, trace, tau)
        rows.append((tau, round(res["avg_plan_ms"], 3), res["migrations"]))
    out = emit(rows, ("tau", "ssm_plan_ms", "migrations"))
    assert all(r["ssm_plan_ms"] < 1000.0 for r in out)  # python-loop budget
    return out


if __name__ == "__main__":
    main()
