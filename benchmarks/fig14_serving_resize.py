"""Fig. 14 (extension): live elastic resize of a REAL serving KV cache.

Everything upstream of this benchmark simulates operator state as byte
counts; here the migrated state is the actual jax decode cache.  Two runs
of the serving driver (``repro.launch.serve.run_serving``) with identical
seeds:

* baseline — decode straight through, no topology change;
* resize   — at ``resize_step`` an SSM-planned elastic event reshards the
  live per-node cache shards (``DeviceBucketedState``), re-routes requests
  by the new bucket ownership, and decode continues.

Checked invariants (the benchmark FAILS, not just reports, on violation):

* generated tokens are bit-identical across the two runs — the migration
  moved state without mutating it;
* bytes_moved > 0 — the event really transferred cache rows (priced from
  the actual leaf shapes/dtypes, not an estimate);
* routing follows the new ownership and ``verify_resharding`` passed.

Reported: steady-state tok/s, the resize-step latency spike vs the steady
per-step time, bytes moved, and the roofline-predicted transfer time
(``roofline.migration_transfer_s`` over the plan's per-phase busiest-link
bytes) next to the measured wall time.  Wall-clock keys carry a ``_wall``
suffix (exempt from the drift gate); plan/byte/phase keys are
deterministic and gated.

    PYTHONPATH=src python -m benchmarks.fig14_serving_resize [--smoke]
"""
import argparse

import numpy as np

from repro.launch.serve import run_serving
from .common import write_bench_json

SMOKE = dict(arch="qwen2.5-3b", requests=16, prompt_len=8, gen=10,
             buckets=16, nodes=2, resize_step=4, resize_to=3)
FULL = dict(arch="qwen2.5-3b", requests=32, prompt_len=16, gen=16,
            buckets=32, nodes=2, resize_step=6, resize_to=4)


def run(smoke: bool) -> dict:
    p = SMOKE if smoke else FULL
    common = dict(arch=p["arch"], smoke=True, requests=p["requests"],
                  prompt_len=p["prompt_len"], gen=p["gen"],
                  buckets=p["buckets"], nodes=p["nodes"], seed=0)
    base = run_serving(resize=None, **common)
    res = run_serving(resize=(p["resize_step"], p["resize_to"]), **common)
    r = res.resize
    assert r is not None, "resize never fired"

    tokens_match = bool(np.array_equal(base.tokens, res.tokens))
    assert tokens_match, "decode diverged across the resize"
    assert r["bytes_moved"] > 0, "elastic event moved no real state"
    assert r["routing_ok"], "requests not routed by new ownership"
    assert r["verified"], "resharding verification did not run"
    assert r["n_after"] == p["resize_to"], (r["n_after"], p["resize_to"])

    payload = {
        "config": {k: p[k] for k in ("arch", "requests", "prompt_len",
                                     "gen", "buckets", "nodes",
                                     "resize_step", "resize_to")},
        # invariants (gated: a False here must fail CI)
        "tokens_match": tokens_match,
        "routing_ok": r["routing_ok"],
        "verified": r["verified"],
        "nodes_after": r["n_after"],
        # deterministic migration quantities (gated)
        "bytes_moved": r["bytes_moved"],
        "moves": r["moves"],
        "phases": r["phases"],
        "plan_cost_bytes": r["plan_cost_bytes"],
        "predicted_transfer_ici_s": r["predicted_ici_s"],
        "predicted_transfer_hbm_s": r["predicted_hbm_s"],
        # wall-clock (machine-dependent, _wall => exempt from the gate)
        "prefill_wall_s": base.prefill_s,
        "steady_step_wall_s": res.steady_s,
        "resize_spike_wall_s": res.spike_s,
        "transfer_wall_s": r["transfer_s_wall"],
        "steady_tok_per_s_wall": (p["requests"] / res.steady_s
                                  if res.steady_s else 0.0),
    }
    print(f"steady {payload['steady_tok_per_s_wall']:.1f} tok/s, "
          f"resize spike {res.spike_s*1e3:.1f}ms "
          f"(steady {res.steady_s*1e3:.1f}ms), "
          f"moved {r['bytes_moved']/1e6:.3f}MB in {r['phases']} phases, "
          f"measured {r['transfer_s_wall']*1e3:.1f}ms vs roofline "
          f"ICI {r['predicted_ici_s']*1e3:.4f}ms / "
          f"HBM {r['predicted_hbm_s']*1e3:.4f}ms")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CPU-friendly variant (CI)")
    args = ap.parse_args(argv)
    payload = run(args.smoke)
    write_bench_json("serving_smoke" if args.smoke else "serving", payload)
    print("FIG14 OK")


if __name__ == "__main__":
    main()
