"""Paper Fig. 8: sliding-window size ω vs result response time.

Larger windows ⇒ more operator state ⇒ heavier migrations ⇒ higher response
times around migrations; MTM-aware stays below single-step.  Response time
comes from the live-migration fluid simulation (runtime/serving.py)."""
import numpy as np

from repro.core import ElasticPlanner
from repro.runtime import ElasticServingSim, SimConfig
from .common import M_MTM, N_HI_MTM, N_LO_MTM, build_pmc, emit, stream

WINDOW_SCALE = (0.5, 1.0, 2.0, 4.0)     # ω multiplier on state sizes


def main():
    w, s0, trace = stream(M_MTM, N_LO_MTM, N_HI_MTM, zipf_a=0.5,
                          burst_prob=0.0)
    rows = []
    for scale in WINDOW_SCALE:
        s = s0 * scale * 2000.0         # sizeable state, like FP windows
        res = {}
        for policy in ("ssm", "mtm"):
            planner = ElasticPlanner(policy=policy, gamma=0.8, pmc_grid=2)
            if policy == "mtm":
                planner.fixed_pmc = build_pmc(w, s, trace, 0.4)[0]
            sim = ElasticServingSim(M_MTM,
                                    SimConfig(bw_bytes_per_s=20e6),
                                    planner, mode="live", tau=0.4)
            mets = sim.run(w, s, trace)
            mig = [x.mean_response_s for x in mets
                   if x.migration_cost_bytes > 0]
            res[policy] = float(np.mean(mig)) if mig else 0.0
        rows.append((scale, round(res["ssm"] * 1e3, 2),
                     round(res["mtm"] * 1e3, 2)))
    out = emit(rows, ("window_scale", "ssm_response_ms", "mtm_response_ms"))
    # response grows with window (state) size
    assert out[-1]["ssm_response_ms"] >= out[0]["ssm_response_ms"]
    return out


if __name__ == "__main__":
    main()
