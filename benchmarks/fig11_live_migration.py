"""Paper Fig. 11: per-minute response time around one migration (workers
10 → 8 at minute 7): our live migration vs the kill-reconfigure-restart
baseline (minimally-modified Storm in the paper).

Expected: kill-restart spikes by orders of magnitude at the migration
minute; live shows a small bump; progressive flattens it further."""
import numpy as np

from repro.core import ElasticPlanner
from repro.runtime import ElasticServingSim, SimConfig
from .common import emit
from repro.data import task_workloads, task_state_sizes


def main():
    m = 32
    T = 15
    # mild skew: per-node capacity must cover the hottest bucket, else the
    # queueing signal is dominated by chronic overload rather than migration
    w = task_workloads(m, T, seed=11, burst_prob=0.0, diurnal_amp=0.05,
                       zipf_a=0.5)
    s = task_state_sizes(w) * 3000.0          # heavy state => long transfer
    trace = np.array([10] * 7 + [8] * (T - 7))
    curves = {}
    for mode in ("kill_restart", "live", "progressive"):
        sim = ElasticServingSim(m, SimConfig(interval_s=60.0),
                                ElasticPlanner(policy="ssm"),
                                mode=mode, tau=0.6)
        mets = sim.run(w, s, trace)
        curves[mode] = [round(x.mean_response_s * 1e3, 2) for x in mets]
    rows = [(t, curves["kill_restart"][t], curves["live"][t],
             curves["progressive"][t]) for t in range(T)]
    out = emit(rows, ("minute", "kill_restart_ms", "live_ms",
                      "progressive_ms"))
    mig_minute = 7
    assert out[mig_minute]["kill_restart_ms"] > \
        5 * out[mig_minute]["live_ms"]
    return out


if __name__ == "__main__":
    main()
