"""Paper Fig. 4: load-balance threshold τ vs migration cost (% of total
state) for ad hoc (Storm default), optimal single-step (SSM), and
MTM-aware migration.

MTM runs at the complete-table scale (m=12, nodes 3..6, every balanced
partition enumerated) so the MDP isn't clipped by table sampling; SSM and
ad hoc run on the same stream.  Expected shape (paper): ad hoc ≫ SSM ≥ MTM
(on average over the trace); SSM/MTM costs decrease as τ grows.
"""
import numpy as np

from .common import (
    M_SMALL, N_HI_SMALL, N_LO_SMALL, build_pmc, emit,
    run_policy_over_trace, stream,
)

TAUS = (0.4, 0.6, 0.8, 1.2, 1.6)


def main():
    w, s, trace = stream(M_SMALL, N_LO_SMALL, N_HI_SMALL, zipf_a=0.5,
                         burst_mult=3.0)
    rows = []
    for tau in TAUS:
        res_adhoc = run_policy_over_trace("adhoc", w, s, trace, tau)
        res_ssm = run_policy_over_trace("ssm", w, s, trace, tau)
        pmc_res, _ = build_pmc(w, s, trace, tau, grid=1, limit_per_k=None)
        res_mtm = run_policy_over_trace("mtm", w, s, trace, tau,
                                        pmc_result=pmc_res)
        rows.append((tau, round(res_adhoc["avg_cost_pct"], 2),
                     round(res_ssm["avg_cost_pct"], 2),
                     round(res_mtm["avg_cost_pct"], 2),
                     res_ssm["migrations"]))
    out = emit(rows, ("tau", "adhoc_cost_pct", "ssm_cost_pct",
                      "mtm_cost_pct", "migrations"))
    # paper-shape assertions
    assert all(r["adhoc_cost_pct"] > r["ssm_cost_pct"] for r in out)
    assert np.mean([r["ssm_cost_pct"] - r["mtm_cost_pct"]
                    for r in out]) >= -0.5   # MTM ≤ SSM on average
    assert out[-1]["ssm_cost_pct"] <= out[0]["ssm_cost_pct"]
    return out


if __name__ == "__main__":
    main()
