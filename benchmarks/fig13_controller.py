"""Fig. 13 (extension): closed-loop controller vs always/never-migrate.

The paper optimizes each migration in isolation; this benchmark evaluates
the *whether/when* layer built on top of it (runtime.control): a
``MigrationPolicy`` that weighs the queueing-model latency gain of a
candidate SSM plan against its pause cost, with hysteresis (trigger
τ > plan τ), patience, and cooldown — the gain-vs-cost decision of
Volnes et al. (2203.03501) with the elasticity policies of Shukla &
Simmhan (1712.00605).

Protocol: each ``runtime.scenarios`` scenario (diurnal wave, flash crowd,
hot-key skew drift, node loss, capacity flapping) is driven through the
same ``ControlLoop`` under three policies:

* ``controller`` — the closed-loop MigrationPolicy;
* ``always``     — follow the offered node budget and replan on every
                   τ violation or scale event (the legacy sims' behavior);
* ``never``      — never migrate voluntarily (failure recovery only).

Scored on migration-interval p99 (p99 over intervals with a migration,
plus the drain-out interval after; overall p99 when a run never migrates)
and bytes moved.  Headline per-scenario score:

    score = p99_mig · (1 + bytes_moved / mean_total_state)

the product of a latency factor and a relative-network-cost factor; it
degenerates gracefully for never-migrate (bytes = 0 → pure latency), so
one number ranks all three.  The raw product p99_mig · bytes is also
reported and asserted against always-migrate.

Expected shape: the controller beats always-migrate on both factors
(fewer, better-timed migrations; it declines gain-free capacity offers,
since aggregate capacity here is rate-proportional and independent of n)
and beats never-migrate by a latency landslide wherever load moves.
"""
import time

import numpy as np

from repro.core import ElasticPlanner
from repro.runtime import (
    AlwaysMigratePolicy, ControlLoop, NeverMigratePolicy, SCENARIOS,
    SimConfig, VectorizedServingSim, weighted_percentile,
)
from .common import emit, write_bench_json

T = 48
M = 96
VARIANTS = ("controller", "always", "never")


def build_loop(m: int, variant: str) -> ControlLoop:
    sim = SimConfig(interval_s=60.0, bw_bytes_per_s=10e6)
    sv = VectorizedServingSim(
        m, sim, ElasticPlanner(policy="ssm_numpy", tau=0.4), mode="live",
        tau=0.4, record_latency=True)
    policy = {"controller": None,
              "always": AlwaysMigratePolicy(),
              "never": NeverMigratePolicy()}[variant]
    return ControlLoop(sv, policy=policy)


def run_variant(scenario, variant: str) -> dict:
    loop = build_loop(scenario.m, variant)
    rep = loop.run(scenario)
    sv = loop.sim
    vals, wts = sv.latency_samples()
    p99 = weighted_percentile(vals, wts, 99)
    mig = rep.migration_intervals
    mig |= {t + 1 for t in set(mig) if t + 1 < scenario.T}
    if mig:
        mv, mw = sv.latency_samples(intervals=mig)
        p99_mig = weighted_percentile(mv, mw, 99)
        steady = set(range(scenario.T)) - mig
        if steady:
            sv_v, sv_w = sv.latency_samples(intervals=steady)
            p99_steady = weighted_percentile(sv_v, sv_w, 99) \
                if len(sv_v) else 0.0
        else:
            p99_steady = p99
    else:
        p99_mig = p99
        p99_steady = p99
    bytes_moved = rep.bytes_moved
    score = p99_mig * (1.0 + bytes_moved / scenario.total_state_bytes)
    return dict(
        variant=variant, migrations=rep.migrations,
        bytes_moved=round(bytes_moved, 1),
        restored_bytes=round(rep.restored_bytes, 1),
        p99_ms=round(p99 * 1e3, 3),
        migration_p99_ms=round(p99_mig * 1e3, 3),
        steady_p99_ms=round(p99_steady * 1e3, 3),
        raw_product=round(p99_mig * bytes_moved, 1),
        score=round(score, 4),
    )


def main():
    t_start = time.perf_counter()
    results = {}
    rows = []
    for name, factory in SCENARIOS.items():
        scenario = factory(T=T, m=M)
        results[name] = {v: run_variant(scenario, v) for v in VARIANTS}
        for v in VARIANTS:
            r = results[name][v]
            rows.append((name, v, r["migrations"],
                         round(r["bytes_moved"] / 1e6, 3),
                         r["migration_p99_ms"], r["steady_p99_ms"],
                         r["score"]))
    out = emit(rows, ("scenario", "variant", "migrations", "bytes_mb",
                      "migration_p99_ms", "steady_p99_ms", "score"))
    elapsed = time.perf_counter() - t_start
    print(f"# m={M} buckets, T={T} intervals, {elapsed:.1f}s total")

    # acceptance: on flash_crowd and skew_drift the policy-driven
    # controller achieves a lower (migration-interval p99 x bytes-moved)
    # than both baselines — raw product vs always-migrate, and the
    # graceful score (never-migrate moves 0 bytes) vs both
    for name in ("flash_crowd", "skew_drift"):
        ctl, alw, nev = (results[name][v] for v in VARIANTS)
        assert ctl["raw_product"] < alw["raw_product"], \
            f"{name}: controller raw p99*bytes must beat always-migrate"
        assert ctl["score"] < alw["score"], \
            f"{name}: controller score must beat always-migrate"
        assert ctl["score"] < nev["score"], \
            f"{name}: controller score must beat never-migrate"
    # the controller should never migrate more than always-migrate, and
    # capacity flapping must not bait it into churn
    for name in results:
        assert results[name]["controller"]["migrations"] <= \
            results[name]["always"]["migrations"], name
    assert results["capacity_flap"]["controller"]["migrations"] <= 2
    assert elapsed < 240.0, f"must run in <240s, took {elapsed:.1f}s"

    write_bench_json("controller", {
        "config": {"m": M, "T": T, "tau_serve": 0.4,
                   "planner": "ssm_numpy", "interval_s": 60.0,
                   "bw_bytes_per_s": 10e6},
        "scenarios": results,
        "elapsed_s": round(elapsed, 1),
    })
    return out


if __name__ == "__main__":
    main()
