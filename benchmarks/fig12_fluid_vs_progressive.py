"""Fig. 12 (extension): migration-time/latency frontier across all five
strategies — kill_restart vs live vs progressive vs fluid vs batched_fluid
— at production bucket counts.

The paper's Fig. 8/11 study response time around migrations for the §5
designs at m≈64 buckets with the scalar simulator.  This benchmark re-runs
that methodology on the vectorized array engine at m = 10 000 buckets and
adds the two Megaphone-style strategies (Hoffmann et al., 1812.01371):

* ``fluid`` — per-bucket sequencing through the Rödiger phase scheduler,
  each bucket pausing only for its own phase window.  Its pause grows with
  ``fluid_batch``, so it must run at batch=1 to keep the tail flat — and
  then pays the per-phase reconfiguration barrier once per bucket-sized
  phase (tens of phases per rebalance at this scale).
* ``batched_fluid`` — conflict-free parallel rounds built from maximum
  bipartite matchings over (sender, receiver) links.  Each bucket still
  pauses only for its own transfer, **independent of the batch size**, so
  it can ship ``fluid_batch``-bucket batches per round and amortize the
  barrier across far fewer rounds.

Protocol: two elastic events (10 → 8 at t=8, 8 → 12 at t=16) over a 24-
interval trace; per-slot response-time samples weighted by tuples served
are pooled over the run and reported as CDF points (p50/p99, plus p99 and
worst spike restricted to migration±1 intervals), alongside the total
migration time (sum of per-rebalance wall-clock, the paper's Fig. 8 "total
migration time" axis).  Expected shape: kill_restart's CDF has a
catastrophic tail (full-app freeze); progressive bounds the tail via
mini-migrations; fluid flattens the tail further but pays the barrier per
phase; batched_fluid matches fluid's tail at a strictly lower total
migration time.

``--smoke`` runs the same protocol at m=1 000 (seconds, for CI) and writes
``BENCH_fig12_smoke.json``; the full run writes ``BENCH_fig12.json``.
Runs in well under 60 s on CPU (the numpy engine; the jit path is for
m ≳ 10⁵).
"""
import sys
import time

import numpy as np

from repro.core import ElasticPlanner
from repro.data import task_state_sizes, task_workloads
from repro.runtime import (
    SERVING_MODES, SimConfig, VectorizedServingSim, weighted_percentile,
)
from .common import emit, write_bench_json

M = 10_000
M_SMOKE = 1_000
T = 24
MODES = SERVING_MODES
# fluid keeps batch=1 (its per-bucket pause is one phase, and a phase holds
# `batch` buckets); batched_fluid's pause is one bucket regardless of batch,
# so it runs at batch=8 and amortizes the per-round barrier 8×.
BATCH = {"batched_fluid": 8}


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    m = M_SMOKE if smoke else M
    t_start = time.perf_counter()
    w = task_workloads(m, T, seed=12, burst_prob=0.0, diurnal_amp=0.05,
                       zipf_a=0.5)
    s = task_state_sizes(w) * 400.0         # ~heavy aggregate state
    trace = np.array([10] * 8 + [8] * 8 + [12] * (T - 16))
    # 10 MB/s uplinks: a rebalance takes several seconds — long enough that
    # strategy choice shows up in the tail (paper Fig. 11's regime), short
    # enough that the backlog drains within the migration interval.
    # 300 slots/interval (dt = 0.2 s) keeps the steady-state queueing floor
    # well below the migration spikes so the tail is strategy-driven.
    # phase_sync_s = 0.25 s is the Megaphone-style reconfiguration barrier:
    # after every phase/round the coordinator broadcasts the new routing
    # table and waits for acks before the next transfer starts.  It charges
    # the migration clock, not the buckets — which is exactly the axis that
    # separates fluid (one barrier per single-bucket phase) from
    # batched_fluid (one barrier per 8-bucket round).
    sim = SimConfig(interval_s=60.0, bw_bytes_per_s=10e6 * m / M,
                    slots_per_interval=300, phase_sync_s=0.25)
    rows = []
    stats = {}
    for mode in MODES:
        sv = VectorizedServingSim(
            m, sim, ElasticPlanner(policy="greedy"), mode=mode, tau=0.6,
            fluid_batch=BATCH.get(mode, 1), record_latency=True,
            verify="strict")   # every plan passes the PLN catalog or dies
        mets = sv.run(w, s, trace)
        vals, wts = sv.latency_samples()
        # spike window = migration intervals plus the drain-out interval
        # right after (a window crossing the interval boundary dumps its
        # backlog into t+1)
        mig_ts = {x.t for x in mets if x.migration_cost_bytes > 0}
        mig_ts |= {t + 1 for t in mig_ts}
        mv, mw = sv.latency_samples(intervals=mig_ts)
        stats[mode] = dict(
            p50=weighted_percentile(vals, wts, 50),
            p99=weighted_percentile(vals, wts, 99),
            spike_p99=weighted_percentile(mv, mw, 99),
            spike=max(x.max_response_s for x in mets
                      if x.migration_cost_bytes > 0),
            total_mig=sum(x.migration_duration_s for x in mets),
            delivered=sum(x.delivered for x in mets),
        )
        rows.append((mode,
                     round(stats[mode]["p50"] * 1e3, 2),
                     round(stats[mode]["p99"] * 1e3, 2),
                     round(stats[mode]["spike_p99"] * 1e3, 2),
                     round(stats[mode]["spike"] * 1e3, 2),
                     round(stats[mode]["total_mig"], 2),
                     int(stats[mode]["delivered"])))
    out = emit(rows, ("mode", "p50_ms", "p99_ms", "migration_p99_ms",
                      "migration_spike_ms", "total_migration_s",
                      "delivered"))
    elapsed = time.perf_counter() - t_start
    print(f"# m={m} buckets, T={T} intervals, {elapsed:.1f}s total")
    # paper-shape assertions: fluid dominates the non-Megaphone tails ...
    assert stats["fluid"]["spike_p99"] < stats["progressive"]["spike_p99"], \
        "fluid migration-interval p99 must beat progressive"
    assert stats["fluid"]["spike_p99"] < stats["kill_restart"]["spike_p99"], \
        "fluid migration-interval p99 must beat kill_restart"
    assert stats["fluid"]["p99"] <= stats["progressive"]["p99"] + 1e-9
    assert stats["fluid"]["spike"] <= stats["progressive"]["spike"] + 1e-9
    assert stats["fluid"]["spike"] < stats["kill_restart"]["spike"]
    # ... and batched_fluid matches that tail at lower total migration time
    bf, fl = stats["batched_fluid"], stats["fluid"]
    assert bf["total_mig"] < fl["total_mig"], \
        "batched_fluid must finish migrating faster than fluid"
    assert bf["spike_p99"] <= fl["spike_p99"] * 1.05 + 1e-9, \
        "batched_fluid migration-interval p99 must stay at fluid's level"
    assert bf["spike_p99"] < stats["progressive"]["spike_p99"], \
        "batched_fluid migration-interval p99 must beat progressive"
    assert elapsed < 60.0, f"must run in <60s, took {elapsed:.1f}s"
    write_bench_json("fig12_smoke" if smoke else "fig12", {
        "m": m, "T": T, "phase_sync_s": sim.phase_sync_s,
        "fluid_batch": dict(BATCH), "rows": out, "elapsed_s": elapsed,
    })
    return out


if __name__ == "__main__":
    main()
