"""Fig. 12 (extension): latency CDF under migration — fluid vs progressive
vs live vs kill-restart, at production bucket counts.

The paper's Fig. 8/11 study response time around migrations for the §5
designs at m≈64 buckets with the scalar simulator.  This benchmark re-runs
that methodology on the vectorized array engine at m = 10 000 buckets and
adds the Megaphone-style ``fluid`` strategy (Hoffmann et al., 1812.01371):
per-bucket sequencing through the same Rödiger phase scheduler, each bucket
pausing only for its own transfer window.

Protocol: two elastic events (10 → 8 at t=8, 8 → 12 at t=16) over a 24-
interval trace; per-slot response-time samples weighted by tuples served
are pooled over the run and reported as CDF points (p50/p99, plus p99 and
worst spike restricted to migration±1 intervals).  Expected
shape: kill_restart's CDF has a catastrophic tail (full-app freeze);
progressive bounds the tail via mini-migrations; fluid dominates both —
its p99 and worst-case spike are the lowest because no bucket ever waits
for another bucket's transfer.

Runs in well under 60 s on CPU (the numpy engine; the jit path is for
m ≳ 10⁵).
"""
import time

import numpy as np

from repro.core import ElasticPlanner
from repro.data import task_state_sizes, task_workloads
from repro.runtime import (
    SimConfig, VectorizedServingSim, weighted_percentile,
)
from .common import emit

M = 10_000
T = 24
MODES = ("kill_restart", "live", "progressive", "fluid")


def main():
    t_start = time.perf_counter()
    w = task_workloads(M, T, seed=12, burst_prob=0.0, diurnal_amp=0.05,
                       zipf_a=0.5)
    s = task_state_sizes(w) * 400.0         # ~heavy aggregate state
    trace = np.array([10] * 8 + [8] * 8 + [12] * (T - 16))
    # 10 MB/s uplinks: a rebalance takes several seconds — long enough that
    # strategy choice shows up in the tail (paper Fig. 11's regime), short
    # enough that the backlog drains within the migration interval.
    # 300 slots/interval (dt = 0.2 s) keeps the steady-state queueing floor
    # well below the migration spikes so the tail is strategy-driven.
    sim = SimConfig(interval_s=60.0, bw_bytes_per_s=10e6,
                    slots_per_interval=300)
    rows = []
    stats = {}
    for mode in MODES:
        sv = VectorizedServingSim(
            M, sim, ElasticPlanner(policy="greedy"), mode=mode, tau=0.6,
            record_latency=True)
        mets = sv.run(w, s, trace)
        vals, wts = sv.latency_samples()
        # spike window = migration intervals plus the drain-out interval
        # right after (a window crossing the interval boundary dumps its
        # backlog into t+1)
        mig_ts = {x.t for x in mets if x.migration_cost_bytes > 0}
        mig_ts |= {t + 1 for t in mig_ts}
        mv, mw = sv.latency_samples(intervals=mig_ts)
        stats[mode] = dict(
            p50=weighted_percentile(vals, wts, 50),
            p99=weighted_percentile(vals, wts, 99),
            spike_p99=weighted_percentile(mv, mw, 99),
            spike=max(x.max_response_s for x in mets
                      if x.migration_cost_bytes > 0),
            delivered=sum(x.delivered for x in mets),
        )
        rows.append((mode,
                     round(stats[mode]["p50"] * 1e3, 2),
                     round(stats[mode]["p99"] * 1e3, 2),
                     round(stats[mode]["spike_p99"] * 1e3, 2),
                     round(stats[mode]["spike"] * 1e3, 2),
                     int(stats[mode]["delivered"])))
    out = emit(rows, ("mode", "p50_ms", "p99_ms", "migration_p99_ms",
                      "migration_spike_ms", "delivered"))
    elapsed = time.perf_counter() - t_start
    print(f"# m={M} buckets, T={T} intervals, {elapsed:.1f}s total")
    # paper-shape assertions: fluid dominates the alternatives' tails
    assert stats["fluid"]["spike_p99"] < stats["progressive"]["spike_p99"], \
        "fluid migration-interval p99 must beat progressive"
    assert stats["fluid"]["spike_p99"] < stats["kill_restart"]["spike_p99"], \
        "fluid migration-interval p99 must beat kill_restart"
    assert stats["fluid"]["p99"] <= stats["progressive"]["p99"] + 1e-9
    assert stats["fluid"]["spike"] <= stats["progressive"]["spike"] + 1e-9
    assert stats["fluid"]["spike"] < stats["kill_restart"]["spike"]
    assert elapsed < 60.0, f"must run in <60s, took {elapsed:.1f}s"
    return out


if __name__ == "__main__":
    main()
