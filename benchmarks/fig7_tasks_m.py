"""Paper Fig. 7: number of tasks m vs migration cost and SSM runtime.

One underlying stream (generated at m=256) is re-bucketed to coarser m by
summing adjacent buckets, so every point sees the SAME data at different
task granularity — the paper's protocol.  Node range [4, 8] keeps ≥2
buckets/node at the coarsest m (at m≈n the τ cap is frequently infeasible
and the relaxation fallback contaminates the comparison).

Expected shape: cost decreases from coarse to fine granularity; runtime
grows ≈ quadratically (SSM is O(m²·n'))."""
import numpy as np

from repro.data import node_count_trace, task_state_sizes, task_workloads
from .common import SEED, T_INTERVALS, aggregate_buckets, emit, \
    run_policy_over_trace

MS = (16, 32, 64, 128, 256)


def main():
    w_full = task_workloads(256, T_INTERVALS, seed=SEED, zipf_a=0.9)
    trace = node_count_trace(w_full, 4, 8)
    rows = []
    for m in MS:
        w = aggregate_buckets(w_full, m)
        s = task_state_sizes(w)
        res = run_policy_over_trace("ssm", w, s, trace, tau=0.4)
        rows.append((m, round(res["avg_cost_pct"], 2),
                     round(res["avg_plan_ms"], 3)))
    out = emit(rows, ("m", "ssm_cost_pct", "ssm_plan_ms"))
    # coarse -> fine improves cost; runtime grows superlinearly
    assert out[-1]["ssm_cost_pct"] <= out[0]["ssm_cost_pct"] + 1e-9
    assert out[-1]["ssm_plan_ms"] > 4 * out[0]["ssm_plan_ms"]
    return out


if __name__ == "__main__":
    main()
