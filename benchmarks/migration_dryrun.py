"""Migration-step dry run: planner-predicted bytes vs the collective bytes
XLA actually emits.

Two compiled resharding programs over an 8-device elastic axis:

* naive    — ``state[perm]`` with a *dynamic* permutation: GSPMD cannot see
             the pattern and conservatively all-gathers everything
             (plan-INDEPENDENT traffic — the kill-restart analogue).
* planned  — ``make_collective_migration``: the SSM plan compiled into
             phased static ``ppermute``s; per-device wire bytes ==
             phases × bucket bytes, exactly the Rödiger-phase schedule the
             planner predicted (the §5 live-migration executor on ICI).

Runs in a subprocess with 8 host devices so the benchmark suite itself
keeps seeing 1 CPU device.
"""
import json
import subprocess
import sys
from pathlib import Path

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import Assignment, ssm
from repro.runtime import (
    make_collective_migration, make_migration_step, plan_to_permutation,
    required_capacity,
)
from repro.roofline.hlo import analyze

m, chunk, n = 64, 16384, 8
rng = np.random.default_rng(0)
base_w = rng.uniform(0.5, 2.0, m)
s = np.full(m, chunk * 4.0)
mesh = jax.make_mesh((8,), ("data",))
rows = []
for n_old, n_new in [(8, 8), (8, 6), (8, 4), (4, 8)]:
    cuts = np.linspace(0, m, n_old + 1).round().astype(int)
    old = Assignment.from_boundaries(m, list(cuts))
    w = base_w.copy()
    if n_old == n_new:
        w[: m // 8] *= 6.0                       # skew forces a rebalance
    plan = ssm(old, n_new, w, s, 0.3)

    # naive dynamic-gather reshard
    sh = NamedSharding(mesh, P("data", None))
    step = jax.jit(make_migration_step(m), in_shardings=(sh, None),
                   out_shardings=sh)
    with mesh:
        comp = step.lower(jax.ShapeDtypeStruct((m, chunk), jnp.float32),
                          jax.ShapeDtypeStruct((m,), jnp.int32)).compile()
    naive = analyze(comp.as_text(), 8).collective_bytes

    # plan-aware ppermute program
    cap = required_capacity(plan)
    fn, phases, _ = make_collective_migration(plan, n, cap)
    from repro.compat import shard_map
    sharded = shard_map(fn, mesh=mesh, in_specs=P("data"),
                        out_specs=P("data"), check_vma=False)
    with mesh:
        comp2 = jax.jit(sharded).lower(
            jax.ShapeDtypeStruct((n, cap, chunk), jnp.float32)).compile()
    planned = analyze(comp2.as_text(), 8).collective_bytes
    rows.append({
        "n_old": n_old, "n_new": n_new,
        "plan_cost_bytes": plan.cost,
        "phases": phases,
        "naive_bytes_per_dev": naive,
        "planned_bytes_per_dev": planned,
        "expected_planned": phases * chunk * 4,
    })
print(json.dumps(rows))
"""


def main():
    out = subprocess.run([sys.executable, "-c", _CHILD], cwd="/root/repo",
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        print(out.stderr[-3000:])
        raise RuntimeError("migration dryrun child failed")
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    print("n_old,n_new,plan_cost_MB,phases,naive_MB_dev,planned_MB_dev,"
          "saving_x")
    for r in rows:
        saving = r["naive_bytes_per_dev"] / max(r["planned_bytes_per_dev"],
                                                1e-9)
        print(f"{r['n_old']},{r['n_new']},"
              f"{r['plan_cost_bytes']/1e6:.2f},{r['phases']},"
              f"{r['naive_bytes_per_dev']/1e6:.2f},"
              f"{r['planned_bytes_per_dev']/1e6:.2f},{saving:.1f}")
        # the compiled plan-aware program moves exactly the scheduled bytes
        assert abs(r["planned_bytes_per_dev"] - r["expected_planned"]) < 1.0
        assert r["planned_bytes_per_dev"] < r["naive_bytes_per_dev"]
    return rows


if __name__ == "__main__":
    main()
