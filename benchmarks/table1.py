"""Paper Table 1 (§2.2): the motivating 20-task example.

Reproduces both columns' first-step costs under the contiguous-interval
model, shows that greedy per-step-optimal chaining is sequence-suboptimal,
and reports the true 2-step optimum found by OMS.
"""
import numpy as np

from repro.core import Assignment, greedy_sequence, migration_cost, oms, ssm


def main():
    W = np.ones(20)
    S = np.ones(20)
    t1 = Assignment.from_boundaries(20, [0, 13, 20])        # 13, 7
    rows = []
    # paper single-step column: 9,9,2 at cost 4
    t2a = Assignment(20, ((0, 9), (11, 20), (9, 11)))
    rows.append(("paper_single_step_t2", migration_cost(t1, t2a, S), 4))
    # paper alternative column: 8,7,5 at cost 5
    t2b = Assignment(20, ((0, 8), (13, 20), (8, 13)))
    rows.append(("paper_alternative_t2", migration_cost(t1, t2b, S), 5))
    # our SSM single-step optimum at t2
    p2 = ssm(t1, 3, W, S, 0.4)
    rows.append(("ssm_t2", p2.cost, 4))
    # greedy chain over (3 nodes, then 4 nodes)
    g = greedy_sequence(t1, [(3, 0.4), (4, 0.4)], W, S)
    rows.append(("greedy_two_step_total", g.total_cost, None))
    # exact sequence optimum (OMS)
    o = oms(t1, [(3, 0.4), (4, 0.4)], W, S)
    rows.append(("oms_two_step_total", o.total_cost, None))
    print("case,cost,paper_value")
    for name, cost, paper in rows:
        print(f"{name},{cost},{paper if paper is not None else ''}")
    assert o.total_cost <= g.total_cost <= 10.0 + 1e-9
    return rows


if __name__ == "__main__":
    main()
