"""Differential oracle harness: every SSM solver, one instance stream.

All exact solvers in the repo answer the same randomized instances through
one comparison loop (the ilp/cp/brute cost-dict idiom), and must agree —
on *feasibility* exactly, and on the optimal gain to 1e-9 relative:

    brute      boundary-multiset enumeration + bitmask matching (tiny m)
    simple     Simple_SSM O(m²·n·n') reference DP (paper Fig. 12 analogue)
    ssm_numpy  production DP, numpy backend (paper Fig. 14 verbatim)
    ssm_jit    production DP, jit-compiled lax.scan backend (core/ssm_jit)

The stream mixes tiny instances (all four solvers), mid-size ones (brute
excluded by its own size guard), crafted cap-boundary cases (a task weight
exactly equal to the cap (1+τ)W/n′ — the Infeasible-consistency bugs lived
here) and min-cover infeasibilities.  ``scripts/ci.sh fast`` runs this
harness after the fast pytest tier; tests/test_ssm_jit.py runs it in-suite.
"""
from __future__ import annotations

import functools
import time
from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

from repro.core.intervals import Assignment
from repro.core.ssm import Infeasible, brute_force, simple_ssm, ssm

INFEASIBLE = "INFEASIBLE"
RTOL = 1e-9

SOLVERS = {
    "brute": brute_force,
    "simple": simple_ssm,
    "ssm_numpy": functools.partial(ssm, backend="numpy"),
    "ssm_jit": functools.partial(ssm, backend="jit"),
}


def random_instance(rng: np.random.Generator, tiny: bool):
    if tiny:
        m = int(rng.integers(4, 13))
        n_old = int(rng.integers(1, min(5, m) + 1))
        n_new = int(rng.integers(1, 5))
    else:
        m = int(rng.integers(16, 200))
        n_old = int(rng.integers(1, 13))
        n_new = int(rng.integers(1, 13))
    w = rng.uniform(0.2, 2.0, m)
    if rng.random() < 0.3:                      # hot task
        w[rng.integers(0, m)] *= float(rng.uniform(3, 12))
    if rng.random() < 0.3:                      # dead tasks
        w[rng.random(m) < 0.2] = 0.0
    s = rng.uniform(0.1, 3.0, m)
    cuts = np.sort(rng.choice(np.arange(1, m), min(n_old - 1, m - 1),
                              replace=False))
    bounds = [0, *[int(c) for c in cuts], m]
    old = Assignment.from_boundaries(m, bounds)
    tau = float(rng.choice([0.1, 0.25, 0.4, 0.8, 1.6]))
    return old, n_new, w, s, tau


def crafted_instances() -> List[Tuple]:
    """Cap-boundary cases: every solver must call feasibility the same way."""
    out = []
    # single task weight exactly equal to the cap (1+τ)W/n′:
    # W=8, n′=2, τ=0.25 → cap = 5.0 = w[0]; feasible only with tolerance,
    # and then for ALL solvers at once
    w = np.array([5.0, 1.0, 1.0, 1.0])
    s = np.array([2.0, 1.0, 1.0, 1.0])
    old = Assignment.from_boundaries(4, [0, 2, 4])
    out.append((old, 2, w, s, 0.25))
    # a single task strictly above any cap → everyone Infeasible
    out.append((old, 2, np.array([50.0, 1.0, 1.0, 1.0]), s, 0.25))
    # n′ < min cover count: W=21, n′=2, τ=0 → cap 10.5 fits at most 3 tasks
    # (9.0) per interval, so covering 7 tasks needs ≥3 intervals
    w3 = np.full(7, 3.0)
    old3 = Assignment.from_boundaries(7, [0, 3, 7])
    out.append((old3, 2, w3, np.ones(7), 0.0))
    # all-zero weights: cap 0 but every interval weighs 0 → feasible
    out.append((Assignment.from_boundaries(3, [0, 3]), 2,
                np.zeros(3), np.array([1.0, 2.0, 3.0]), 0.4))
    return out


def _answer(fn, inst):
    try:
        return float(fn(*inst).gain)
    except Infeasible:
        return INFEASIBLE


def _agrees(got, ref) -> bool:
    if (got == INFEASIBLE) != (ref == INFEASIBLE):
        return False
    return got == INFEASIBLE or \
        abs(got - ref) <= RTOL * max(1.0, abs(ref))


def run(n_tiny: int = 20, n_big: int = 32, seed: int = 0,
        verbose: bool = True) -> Dict[str, List]:
    rng = np.random.default_rng(seed)
    gains: Dict[str, List] = defaultdict(list)
    times: Dict[str, float] = defaultdict(float)
    bad: List[str] = []
    instances = [(True, random_instance(rng, True)) for _ in range(n_tiny)]
    instances += [(False, random_instance(rng, False))
                  for _ in range(n_big)]
    instances += [(inst[0].m <= 20, inst) for inst in crafted_instances()]
    for i, (tiny, inst) in enumerate(instances):
        answers = {}
        for name, fn in SOLVERS.items():
            if name == "brute" and not tiny:
                continue
            t0 = time.perf_counter()
            answers[name] = _answer(fn, inst)
            times[name] += time.perf_counter() - t0
            gains[name].append(answers[name])
        ref = answers["simple"]
        for name, got in answers.items():
            if not _agrees(got, ref):
                bad.append(f"instance {i} ({'tiny' if tiny else 'big'}, "
                           f"m={inst[0].m}, n'={inst[1]}, tau={inst[4]}): "
                           f"{name}={got} vs simple={ref}")
    n_inf = sum(1 for g in gains["simple"] if g == INFEASIBLE)
    if verbose:
        print(f"ssm_oracles: {len(instances)} instances "
              f"({n_inf} infeasible), solvers agree on feasibility and "
              f"gain @ rtol {RTOL}")
        for name in SOLVERS:
            print(f"  {name:10s} answered {len(gains[name]):3d} "
                  f"in {times[name]:6.2f}s")
    if bad:
        raise AssertionError("oracle disagreement:\n" + "\n".join(bad))
    return gains


def main():
    run()


if __name__ == "__main__":
    main()
