"""Aggregate experiments/dryrun/*.json into the roofline table
(EXPERIMENTS.md §Roofline)."""
import json
from pathlib import Path

COLS = ("arch", "shape", "mesh", "status", "compute_s", "memory_s",
        "collective_s", "bottleneck", "useful_compute_ratio",
        "roofline_fraction", "temp_size_in_bytes", "compile_s")


def load(d="experiments/dryrun", tag=None):
    rows = []
    for p in sorted(Path(d).glob("*.json")):
        rec = json.loads(p.read_text())
        if tag is None and rec.get("schedule", "masked") != "masked":
            continue
        rec.setdefault("variant", "base")
        rows.append(rec)
    return rows


def fmt(x):
    if isinstance(x, float):
        return f"{x:.4g}"
    return str(x)


def main(d="experiments/dryrun", md_out="experiments/roofline_table.md"):
    rows = load(d)
    print(",".join(COLS))
    ok = skipped = failed = 0
    for r in rows:
        print(",".join(fmt(r.get(c, "")) for c in COLS))
        st = r.get("status")
        ok += st == "ok"
        skipped += st == "skipped"
        failed += st == "failed"
    print(f"# ok={ok} skipped={skipped} failed={failed}")
    # markdown table (EXPERIMENTS.md §Roofline companion)
    md = ["| arch | shape | mesh | variant | compute_s | memory_s | "
          "collective_s | bottleneck | useful | roof_frac |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            continue
        md.append("| {arch} | {shape} | {mesh} | {variant} | {c:.4g} | "
                  "{m:.4g} | {k:.4g} | {b} | {u:.3f} | {f:.4f} |".format(
                      arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                      variant=r.get("variant", "base"),
                      c=r["compute_s"], m=r["memory_s"],
                      k=r["collective_s"], b=r["bottleneck"],
                      u=r["useful_compute_ratio"],
                      f=r["roofline_fraction"]))
    from pathlib import Path
    if md_out:
        Path(md_out).parent.mkdir(parents=True, exist_ok=True)
        Path(md_out).write_text("\n".join(md) + "\n")
        print(f"# wrote {md_out} ({len(md)-2} rows)")
    assert failed == 0, "dry-run failures present"
    return rows


if __name__ == "__main__":
    main()
