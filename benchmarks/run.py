"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig4,table1]

Each module prints its own CSV; this driver runs them all, times them, and
fails loudly if any paper-shape assertion breaks.
"""
import argparse
import importlib
import time
import traceback

SUITES = [
    ("table1", "Table 1 — motivating sequence example"),
    ("fig4_cost_vs_tau", "Fig. 4 — τ vs migration cost (adhoc/SSM/MTM)"),
    ("fig5_ssm_runtime",
     "Fig. 5 — τ vs SSM planning time + numpy/jit backend scaling"),
    ("ssm_oracles", "Differential harness — all SSM solvers must agree"),
    ("fig6_pmc_time", "Fig. 6 — τ vs PMC precompute time"),
    ("fig7_tasks_m", "Fig. 7 — #tasks m vs cost & runtime"),
    ("fig8_window_response", "Fig. 8 — window size vs response time"),
    ("fig9_10_gamma", "Figs. 9/10 — γ vs cost & precompute"),
    ("fig11_live_migration", "Fig. 11 — live vs kill-restart"),
    ("fig12_fluid_vs_progressive",
     "Fig. 12 — five-strategy migration frontier incl. batched_fluid "
     "(m=10k, vectorized)"),
    ("fig13_controller",
     "Fig. 13 — closed-loop controller vs always/never-migrate"),
    ("migration_dryrun", "Dry-run — planner cost vs HLO collective bytes"),
    ("roofline_report", "Roofline — dry-run term table"),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)
    only = None
    if args.only:
        only = {name for name in args.only.split(",") if name}
        known = {mod_name for mod_name, _ in SUITES}
        unknown = sorted(only - known)
        if unknown:
            raise SystemExit(
                f"--only: unknown suite(s) {unknown}; choose from "
                f"{sorted(known)}")
        if not only:
            raise SystemExit("--only: no suites selected")
    failures = []
    for mod_name, title in SUITES:
        if only and mod_name not in only:
            continue
        print(f"\n=== {title} [{mod_name}] " + "=" * 20)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            mod.main()
            print(f"--- {mod_name} ok in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures.append(mod_name)
            traceback.print_exc()
            print(f"--- {mod_name} FAILED in {time.time()-t0:.1f}s: {e}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nALL BENCHMARKS PASSED")


if __name__ == "__main__":
    main()
