"""Shared benchmark protocol (paper §6, adapted to the offline container).

Paper protocol: Twitter crawl, m=64 tasks, nodes normalized into [8,16],
one migration whenever the per-interval node count changes, 100 consecutive
migrations, averages reported per migration.

Offline adaptation (documented in EXPERIMENTS.md): the synthetic bursty-Zipf
stream reproduces the crawl's diurnal rate/skew/burst structure; MTM-aware
runs use m=24, nodes∈[6,10] and a grid-2 partition table so PMC fits this
container (the paper used a Spark cluster for hundreds of minutes; our
grid coarsening is a measured-loss approximation, see fig6).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import (
    Assignment, ElasticPlanner, MTM, PartitionTable, adhoc, greedy_trim,
    mtm_aware_plan, pmc, ssm,
)
from repro.core.ssm import Infeasible
from repro.data import node_count_trace, task_state_sizes, task_workloads

# full-protocol scale (ssm/adhoc/greedy)
M_FULL, N_LO, N_HI = 64, 8, 16
# reduced MTM scale (PMC table must fit the container)
M_MTM, N_LO_MTM, N_HI_MTM = 24, 6, 10
# complete-table MTM scale: every balanced partition enumerable, so the
# MDP optimality claim (Fig. 4/9) is tested without sampling artifacts
M_SMALL, N_LO_SMALL, N_HI_SMALL = 12, 3, 6
T_INTERVALS = 120
SEED = 7


def stream(m: int, n_lo: int, n_hi: int, seed: int = SEED, **kw):
    w = task_workloads(m, T_INTERVALS, seed=seed, **kw)
    s = task_state_sizes(w)
    trace = node_count_trace(w, n_lo, n_hi)
    return w, s, trace


def aggregate_buckets(w: np.ndarray, m_target: int) -> np.ndarray:
    """Coarsen a [T, m] stream to m_target buckets by summing adjacent
    buckets — the SAME data at different task granularity (paper Fig. 7
    varies m on one dataset)."""
    T, m = w.shape
    assert m % m_target == 0
    f = m // m_target
    return w.reshape(T, m_target, f).sum(axis=2)


def initial_assignment(m: int, n: int) -> Assignment:
    cuts = np.linspace(0, m, n + 1).round().astype(int)
    return Assignment.from_boundaries(m, list(cuts))


def run_policy_over_trace(policy: str, w, s, trace, tau: float,
                          pmc_result=None) -> Dict[str, float]:
    """Paper protocol: migrate at every node-count change; report average
    migration cost as % of total state and mean planning time."""
    m = w.shape[1]
    assign = initial_assignment(m, int(trace[0]))
    costs, times, n_migs = [], [], 0
    for t in range(1, len(trace)):
        n_new = int(trace[t])
        n_cur = sum(1 for lo, hi in assign.intervals if hi > lo)
        if n_new == n_cur:
            continue
        t0 = time.perf_counter()
        try:
            if policy == "mtm":
                plan = mtm_aware_plan(assign, n_new, s[t], pmc_result)
            elif policy == "ssm":
                plan = ssm(assign, n_new, w[t], s[t], tau)
            elif policy == "adhoc":
                plan = adhoc(assign, n_new, w[t], s[t], tau)
            elif policy == "greedy":
                plan = greedy_trim(assign, n_new, w[t], s[t], tau)
            else:
                raise ValueError(policy)
        except Infeasible:
            # a burst can push one bucket past any cap: relax τ
            # geometrically (paper §2.1 lets the user loosen τ)
            t_try = tau
            while True:
                t_try = t_try * 2 + 0.5
                try:
                    plan = ssm(assign, n_new, w[t], s[t], t_try)
                    break
                except Infeasible:
                    if t_try > 64:
                        raise
        times.append(time.perf_counter() - t0)
        costs.append(plan.cost / max(s[t].sum(), 1e-12) * 100.0)
        assign = plan.new
        n_migs += 1
    return {
        "avg_cost_pct": float(np.mean(costs)) if costs else 0.0,
        "avg_plan_ms": float(np.mean(times) * 1e3) if times else 0.0,
        "migrations": n_migs,
    }


def build_pmc(w, s, trace, tau: float, gamma: float = 0.8,
              grid: int = 2, gain_fn=None, limit_per_k: int = 1200):
    """Offline PMC phase (paper §4.2): MTM estimated from the node-count
    history; the partition table is built on time-averaged workloads."""
    w_avg = w.mean(axis=0)
    s_avg = s.mean(axis=0)
    n_lo, n_hi = int(trace.min()), int(trace.max())
    mtm = MTM.estimate(list(trace), n_lo, n_hi)
    t0 = time.perf_counter()
    table = PartitionTable.build(w_avg, n_lo, n_hi, tau, grid=grid,
                                 limit_per_k=limit_per_k)
    kwargs = {"gain_fn": gain_fn} if gain_fn is not None else {}
    res = pmc(table, s_avg, mtm, gamma, **kwargs)
    precompute_s = time.perf_counter() - t0
    return res, precompute_s


REPO_ROOT = Path(__file__).resolve().parent.parent


def write_bench_json(name: str, payload: Dict) -> Path:
    """Persist a machine-readable benchmark artifact as
    ``BENCH_<name>.json`` at the repo root (the stdout CSV is for humans,
    this file is for tooling/regression tracking).  Overwrites atomically
    so a crashed run never leaves a torn file."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    tmp.replace(path)
    print(f"wrote {path.name}")
    return path


def emit(rows: List[Tuple], header: Tuple) -> List[Dict]:
    print(",".join(header))
    out = []
    for r in rows:
        print(",".join(str(x) for x in r))
        out.append(dict(zip(header, r)))
    return out
