#!/usr/bin/env python
"""Benchmark regression gate: freshly-written BENCH_*.json vs committed.

``benchmarks/run.py`` asserts paper *shapes* (A beats B) but will happily
print ALL BENCHMARKS PASSED while absolute numbers drift.  This gate
compares every ``BENCH_*.json`` in the working tree against the version
committed at HEAD and fails on numeric drift beyond tolerance or any
structural change, so a benchmark regression cannot land silently.

    python scripts/check_bench.py [--rtol 1e-6] [--ref HEAD] [files...]

Wall-clock timing fields (elapsed/plan-time/first/steady seconds) are
exempt — they measure the machine, not the code.  Files present only in
the working tree are reported as new and PASS with a notice (a
benchmark-adding PR needs no two-commit dance; commit the JSON to start
gating it); files committed but deleted from the tree fail.  Only a
genuinely absent path is treated as "new" — a bad ``--ref`` or a broken
git invocation is a hard error (exit 2), never a silent pass.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# wall-clock keys: machine-dependent, never gated
TIMING_KEY = re.compile(
    r"(^|_)(elapsed|wall|time)(_|$)"
    r"|(^|_)(first|steady|plan|precompute)_(s|ms)$")


def is_timing_key(key: str) -> bool:
    return bool(TIMING_KEY.search(key))


class GitError(RuntimeError):
    """git itself failed (bad ref, not a repository, …) — distinct from a
    path that simply doesn't exist at the ref."""


def resolve_ref(ref: str) -> str:
    """Fail fast on a ref that names no commit, so a typo'd --ref can't
    silently turn every baseline into 'new file, pass'."""
    proc = subprocess.run(
        ["git", "rev-parse", "--verify", "--quiet", f"{ref}^{{commit}}"],
        cwd=REPO, capture_output=True, text=True)
    if proc.returncode != 0:
        raise GitError(f"--ref {ref!r} does not name a commit"
                       + (f": {proc.stderr.strip()}" if proc.stderr.strip()
                          else ""))
    return proc.stdout.strip()


def committed(name: str, ref: str) -> str | None:
    """Baseline text at ``ref``, or None iff the path doesn't exist there
    (a new benchmark).  Any other git failure raises GitError."""
    proc = subprocess.run(
        ["git", "show", f"{ref}:{name}"], cwd=REPO,
        capture_output=True, text=True)
    if proc.returncode == 0:
        return proc.stdout
    err = proc.stderr.strip()
    if "does not exist" in err or "exists on disk, but not in" in err:
        return None
    raise GitError(f"git show {ref}:{name} failed: {err}")


def diff(base, fresh, rtol: float, path: str = "") -> list:
    """Recursive compare; returns a list of human-readable mismatches."""
    errs: list = []
    if isinstance(base, dict) and isinstance(fresh, dict):
        for k in sorted(set(base) | set(fresh)):
            sub = f"{path}.{k}" if path else k
            if k not in base:
                errs.append(f"{sub}: new key (not in baseline)")
            elif k not in fresh:
                errs.append(f"{sub}: key missing from fresh output")
            elif is_timing_key(k):
                continue
            else:
                errs.extend(diff(base[k], fresh[k], rtol, sub))
    elif isinstance(base, list) and isinstance(fresh, list):
        if len(base) != len(fresh):
            errs.append(f"{path}: length {len(base)} -> {len(fresh)}")
        else:
            for i, (b, f) in enumerate(zip(base, fresh)):
                errs.extend(diff(b, f, rtol, f"{path}[{i}]"))
    elif isinstance(base, (int, float)) and isinstance(fresh, (int, float)) \
            and not isinstance(base, bool) and not isinstance(fresh, bool):
        if not math.isclose(float(base), float(fresh), rel_tol=rtol,
                            abs_tol=rtol):
            errs.append(f"{path}: {base} -> {fresh} (rtol {rtol})")
    elif base != fresh:
        errs.append(f"{path}: {base!r} -> {fresh!r}")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json files (default: all in repo root)")
    ap.add_argument("--rtol", type=float, default=1e-6)
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the baselines")
    args = ap.parse_args(argv)

    names = args.files or sorted(
        os.path.basename(p)
        for p in glob.glob(os.path.join(REPO, "BENCH_*.json")))
    if not names:
        print("check_bench: no BENCH_*.json files found")
        return 1
    try:
        resolve_ref(args.ref)
    except GitError as e:
        print(f"check_bench: {e}")
        return 2
    failed = False
    for name in names:
        fresh_path = os.path.join(REPO, name)
        try:
            base_text = committed(name, args.ref)
        except GitError as e:
            print(f"check_bench: {e}")
            return 2
        if not os.path.exists(fresh_path):
            if base_text is not None:
                print(f"FAIL {name}: committed baseline but no fresh file")
                failed = True
            continue
        if base_text is None:
            print(f"NEW  {name}: not present at {args.ref} — new "
                  f"benchmark, passing (commit it to start gating)")
            continue
        with open(fresh_path) as fh:
            fresh = json.load(fh)
        errs = diff(json.loads(base_text), fresh, args.rtol)
        if errs:
            failed = True
            print(f"FAIL {name}: {len(errs)} mismatch(es)")
            for e in errs[:20]:
                print(f"  {e}")
            if len(errs) > 20:
                print(f"  ... and {len(errs) - 20} more")
        else:
            print(f"OK   {name}")
    if failed:
        print("check_bench: benchmark outputs drifted from committed "
              "baselines (re-run benchmarks; if the change is intended, "
              "commit the new JSON)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
