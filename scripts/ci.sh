#!/usr/bin/env bash
# Tier-1 CI entrypoint.
#
#   scripts/ci.sh          — the ROADMAP.md tier-1 command (full suite)
#   scripts/ci.sh fast     — fast path: lint + skip @slow jit/model tests
#   scripts/ci.sh lint     — static analysis only (jaxlint + plancheck
#                            smoke; `make lint`)
#
# Runs on a bare jax+numpy+pytest container (the hypothesis property tests
# fall back to the vendored shim in tests/_vendor); install
# requirements-dev.txt for full Hypothesis runs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "lint" || "${1:-}" == "fast" ]]; then
    # static analysis: jaxlint (JAX001..006) must be clean over src/, and
    # one SSM plan per strategy must pass the plancheck catalog
    # (PLN001..006) — see src/repro/analysis/
    python -m repro.analysis.jaxlint src/repro
    python scripts/lint_plans.py
fi
if [[ "${1:-}" == "lint" ]]; then
    exit 0
fi

if [[ "${1:-}" == "fast" ]]; then
    python -m pytest -x -q -m "not slow"
    # closed-loop controller must beat always/never-migrate, and the
    # refreshed BENCH json must match the committed baselines
    python -m benchmarks.fig13_controller
    python scripts/check_bench.py BENCH_controller.json
    # five-strategy migration frontier at smoke scale: batched_fluid must
    # beat fluid on total migration time at fluid's tail latency
    python -m benchmarks.fig12_fluid_vs_progressive --smoke
    python scripts/check_bench.py BENCH_fig12_smoke.json
    # real-state serving resize: the live elastic event must move the
    # actual KV cache bit-identically (tokens match a no-resize run)
    python -m benchmarks.fig14_serving_resize --smoke
    python scripts/check_bench.py BENCH_serving_smoke.json
    # differential gate: every SSM solver (brute/simple/numpy/jit) must
    # agree on feasibility and optimal gain across the randomized stream
    exec python -m benchmarks.ssm_oracles
fi
exec python -m pytest -x -q
