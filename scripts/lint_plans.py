#!/usr/bin/env python
"""Plan linter CLI: run the ``analysis.plancheck`` rule catalog
(PLN001..PLN006) against real planner output.

Two modes:

* default — plan one seeded scale-up, scale-down, and rebalance with the
  SSM planner and verify every strategy's schedule/windows for each
  (the "one plan per strategy" smoke CI runs in ``scripts/ci.sh fast``);
* ``--scenario NAME`` (or ``--all-scenarios``) — replay the full closed
  control loop on a scenario from ``runtime.scenarios`` with
  ``verify="strict"``, so every plan behind every DecisionRecord in the
  audit log is checked the moment it is made; prints the decision log
  of the migrations that were verified.

Exit status 0 = every plan clean; 1 = findings (printed per rule).

Examples::

    PYTHONPATH=src python scripts/lint_plans.py
    PYTHONPATH=src python scripts/lint_plans.py --scenario flash_crowd
    PYTHONPATH=src python scripts/lint_plans.py --all-scenarios -v
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.analysis import format_findings, verify_migration
from repro.core import Assignment, ElasticPlanner
from repro.runtime.serving import SERVING_MODES

BATCH = {"batched_fluid": 8}          # fig12's batch for the batched mode


def _even(m: int, n: int) -> Assignment:
    cuts = np.linspace(0, m, n + 1).round().astype(int)
    return Assignment.from_boundaries(m, list(cuts))


def lint_strategies(m: int = 256, seed: int = 0, tau: float = 0.4,
                    verbose: bool = False) -> int:
    """One plan per strategy per scale event, fully verified."""
    rng = np.random.default_rng(seed)
    w = rng.pareto(1.5, m) + 0.1
    s = rng.pareto(1.5, m) * 1e6 + 1e5
    planner = ElasticPlanner(policy="ssm")
    events = [("scale_up", 5, 8), ("scale_down", 8, 3),
              ("rebalance", 6, 6)]
    bad = 0
    for label, n0, n1 in events:
        assign = _even(m, n0)
        plan = planner.plan(assign, n1, w, s, tau=tau)
        for mode in SERVING_MODES:
            findings = verify_migration(
                plan, s, mode=mode, fluid_batch=BATCH.get(mode, 1),
                w=w, tau=tau, n_target=n1,
                relax_tau_max=planner.relax_tau_max, expected_old=assign)
            status = "ok" if not findings else "FAIL"
            if findings or verbose:
                print(f"{label:>10} {n0}->{n1} {mode:<14} {status}")
            for f in findings:
                print(f"    {f}")
            bad += len(findings)
    moved = "clean" if not bad else f"{bad} finding(s)"
    print(f"lint_plans: strategies x events = "
          f"{len(SERVING_MODES) * len(events)} plans verified — {moved}")
    return 1 if bad else 0


def lint_scenario(name: str, mode: str = "live",
                  verbose: bool = False) -> int:
    """Replay the closed loop with verify='strict': every DecisionRecord's
    plan passes the full catalog or the run aborts with the findings."""
    from repro.analysis import PlanVerificationError
    from repro.runtime import scenarios
    from repro.runtime.control import ControlLoop
    from repro.runtime.serving import ElasticServingSim, SimConfig

    scen = scenarios.make(name)
    planner = ElasticPlanner(policy="ssm")
    sim = ElasticServingSim(scen.m, SimConfig(), planner, mode=mode,
                            verify="strict")
    try:
        report = ControlLoop(sim).run(scen)
    except PlanVerificationError as e:
        print(f"lint_plans[{name}]: FAIL\n{e}")
        return 1
    migrated = [d for d in report.decisions if d.migrated]
    print(f"lint_plans[{name}]: {len(report.decisions)} decisions, "
          f"{len(migrated)} migrations — every plan clean")
    if verbose:
        for d in migrated:
            print(f"  t={d.t:>3} {d.action:<10} n {d.n_before}->"
                  f"{d.n_after} strategy={d.strategy or mode} "
                  f"bytes={d.cost_bytes:.3g} ({d.reason})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", help="replay one scenario from "
                                       "runtime.scenarios under "
                                       "verify='strict'")
    ap.add_argument("--all-scenarios", action="store_true",
                    help="replay every scenario in the catalog")
    ap.add_argument("--mode", default="live",
                    help="strategy for scenario replay (default live)")
    ap.add_argument("--m", type=int, default=256,
                    help="buckets for the strategy smoke (default 256)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.all_scenarios:
        from repro.runtime import scenarios
        rc = 0
        for name in scenarios.SCENARIOS:
            rc |= lint_scenario(name, mode=args.mode,
                                verbose=args.verbose)
        return rc
    if args.scenario:
        return lint_scenario(args.scenario, mode=args.mode,
                             verbose=args.verbose)
    return lint_strategies(m=args.m, seed=args.seed,
                           verbose=args.verbose)


if __name__ == "__main__":
    sys.exit(main())
