"""Sharded checkpointing with topology-aware restore (restore IS a
migration).

Layout on disk:
    <dir>/step_<k>/manifest.json      m, boundaries, per-bucket bytes, extra
    <dir>/step_<k>/bucket_<j>.npz     one file per bucket (the task state)
    <dir>/step_<k>/extra.npz          non-bucketed tree (params, opt state)

Restore onto n' nodes plans with SSM from the checkpoint's assignment:
nodes that survive a restart re-open their local buckets (zero read), and
only reassigned buckets hit storage — checkpoint-restart cost becomes the
paper's migration cost.  ``save`` is atomic (tmp + rename) and optionally
asynchronous (background thread), so the train loop never blocks on fsync.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import Assignment, MigrationPlan, ssm
from .state import BucketedState


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _sub(flat: Dict[str, np.ndarray], key: str) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    for kk, vv in flat.items():
        parts = kk.split("/", 1)
        if parts[0] == key:
            out[parts[1] if len(parts) > 1 else ""] = vv
    return out


def _unflatten(flat: Dict[str, np.ndarray], proto) -> Any:
    if isinstance(proto, dict):
        return {k: _unflatten(_sub(flat, k), v) for k, v in proto.items()}
    if isinstance(proto, (list, tuple)):
        seq = [_unflatten(_sub(flat, str(i)), v)
               for i, v in enumerate(proto)]
        return type(proto)(seq)
    return flat[""] if "" in flat else next(iter(flat.values()))


def _describe(tree) -> Any:
    """JSON-able structure descriptor of a pytree (save-side companion of
    ``_unflatten``: ``save`` flattens nested trees to ``a/b`` keys, so the
    manifest must record the nesting to restore it losslessly)."""
    if isinstance(tree, dict):
        return {"kind": "dict",
                "keys": {k: _describe(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"kind": "tuple" if isinstance(tree, tuple) else "list",
                "items": [_describe(v) for v in tree]}
    return {"kind": "leaf"}


def _proto(desc) -> Any:
    """Turn a ``_describe`` descriptor back into an ``_unflatten`` proto
    (leaves are placeholders — only the container structure matters)."""
    if desc["kind"] == "dict":
        return {k: _proto(d) for k, d in desc["keys"].items()}
    if desc["kind"] == "list":
        return [_proto(d) for d in desc["items"]]
    if desc["kind"] == "tuple":
        return tuple(_proto(d) for d in desc["items"])
    return None


@dataclass
class RestoreReport:
    plan: Optional[MigrationPlan]
    bytes_read: float            # storage reads (reassigned buckets)
    bytes_resident: float        # buckets reopened in place (no read)
    files_read: int = 0          # bucket files actually opened
    files_resident: int = 0      # buckets served from in-memory state


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: BucketedState, assignment: Assignment,
             extra: Any = None, async_: bool = False) -> None:
        descs = [_describe(b) for b in state.buckets]
        extra_desc = _describe(extra) if extra is not None else None
        if async_:
            self.wait()
            snap_buckets = [
                {k: np.array(v) for k, v in _flatten(b).items()}
                for b in state.buckets]
            extra_flat = _flatten(extra) if extra is not None else None
            self._thread = threading.Thread(
                target=self._write, args=(step, snap_buckets, assignment,
                                          extra_flat, descs, extra_desc),
                daemon=True)
            self._thread.start()
        else:
            snap = [_flatten(b) for b in state.buckets]
            self._write(step, snap, assignment,
                        _flatten(extra) if extra is not None else None,
                        descs, extra_desc)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step, flat_buckets, assignment, extra_flat,
               descs=None, extra_desc=None):
        final = self.dir / f"step_{step}"
        tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_"))
        try:
            sizes = []
            for j, flat in enumerate(flat_buckets):
                np.savez(tmp / f"bucket_{j}.npz", **flat)
                sizes.append(float(sum(v.nbytes for v in flat.values())))
            if extra_flat is not None:
                np.savez(tmp / "extra.npz", **extra_flat)
            manifest = {
                "step": step,
                "m": len(flat_buckets),
                "intervals": list(map(list, assignment.intervals)),
                "bucket_bytes": sizes,
                "has_extra": extra_flat is not None,
            }
            if descs:
                # one descriptor when uniform (the common case: m can be
                # 10k+), the full per-bucket list otherwise
                if all(d == descs[0] for d in descs):
                    manifest["bucket_tree"] = descs[0]
                else:
                    manifest["bucket_trees"] = descs
            if extra_desc is not None:
                manifest["extra_tree"] = extra_desc
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
        finally:
            if tmp.exists():
                shutil.rmtree(tmp, ignore_errors=True)
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- load ---------------------------------------------------------------
    def steps(self) -> List[int]:
        return sorted(int(p.name.split("_")[1])
                      for p in self.dir.glob("step_*"))

    def latest(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def manifest(self, step: int) -> dict:
        return json.loads(
            (self.dir / f"step_{step}" / "manifest.json").read_text())

    def restore(self, step: int, n_new: int, w: np.ndarray, tau: float,
                extra_proto: Any = None,
                alive_nodes: Optional[set] = None,
                resident_state: Optional[BucketedState] = None
                ) -> Tuple[BucketedState, Assignment, RestoreReport, Any]:
        """Restore onto ``n_new`` nodes.  ``alive_nodes``: node ids whose
        local buckets survive in memory/disk-cache (their buckets are free
        to reopen); default: all checkpoint nodes survive.

        ``resident_state``: the surviving in-memory BucketedState.  When
        given, buckets the plan counts as resident are taken from it and
        their ``bucket_*.npz`` files are never opened — the report's
        read/resident split then matches the actual I/O exactly (asserted).
        Without it every bucket is read from storage (a cold restart), and
        ``files_read == m`` records that.

        Buckets are un-flattened back to the pytree structure recorded at
        save time (older checkpoints without the descriptor fall back to
        flat ``{"a/b": arr}`` dicts).
        """
        man = self.manifest(step)
        m = man["m"]
        old = Assignment(m, tuple(tuple(iv) for iv in man["intervals"]))
        s = np.asarray(man["bucket_bytes"])
        plan = ssm(old, n_new, np.asarray(w, dtype=np.float64), s, tau)
        owner_old = old.owner_of()
        n_total = max(old.n_nodes, plan.new.n_nodes)
        owner_new = plan.new.padded(n_total).owner_of()
        alive = set(range(old.n_nodes)) if alive_nodes is None else alive_nodes
        descs = man.get("bucket_trees") or (
            [man["bucket_tree"]] * m if "bucket_tree" in man else None)
        buckets = []
        read = resident = 0.0
        files_read = files_resident = 0
        base = self.dir / f"step_{step}"
        for j in range(m):
            is_resident = (owner_new[j] == owner_old[j]
                           and owner_old[j] in alive)
            if is_resident and resident_state is not None:
                buckets.append(resident_state.buckets[j])
                files_resident += 1
            else:
                flat = dict(np.load(base / f"bucket_{j}.npz"))
                buckets.append(_unflatten(flat, _proto(descs[j]))
                               if descs else flat)
                files_read += 1
            if is_resident:
                resident += s[j]
            else:
                read += s[j]
        if resident_state is not None:
            # accounting must match the files actually opened
            expected = int(sum(1 for j in range(m)
                               if not (owner_new[j] == owner_old[j]
                                       and owner_old[j] in alive)))
            assert files_read == expected, (files_read, expected)
        extra = None
        if man["has_extra"]:
            proto = extra_proto if extra_proto is not None else (
                _proto(man["extra_tree"]) if "extra_tree" in man else None)
            if proto is not None:
                extra = _unflatten(dict(np.load(base / "extra.npz")), proto)
        state = BucketedState(buckets)
        return state, plan.new, RestoreReport(
            plan, read, resident,
            files_read=files_read, files_resident=files_resident), extra
