"""Fault tolerance: failure recovery as a migration, straggler mitigation as
weighted balance (paper §8 "future work: apply our migration techniques to
fault recovery" — implemented here).

Failure recovery
----------------
When node(s) die, their buckets must be restored from the last checkpoint
*wherever they land* — that restore cost is strategy-independent.  Setting
``s_j := 0`` for the lost buckets therefore makes SSM optimize exactly the
right objective: keep the survivors' state in place, balance the load, and
let the lost buckets fall anywhere.  Dead node ids are relabeled off the
plan afterwards (they can only hold zero-gain intervals, so relabeling
changes nothing).

Straggler mitigation
--------------------
A straggler (slow node) is handled by generalizing Def. 2.1 to weighted
capacity: node i's budget is (1+τ)·W·speed_i/Σspeed.  SSM's DP assumes
node-anonymous caps, so we quantize speeds into *virtual nodes*: a node at
relative speed q gets round(q·granularity) virtual slots; SSM plans over
virtual slots; slots then collapse back to physical nodes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import Assignment, MigrationPlan, ssm
from repro.core.ssm import _plan


def recovery_plan(old: Assignment, failed: Set[int], n_new: int,
                  w: np.ndarray, s: np.ndarray, tau: float
                  ) -> MigrationPlan:
    """Plan after losing ``failed`` node ids (restore-from-checkpoint cost is
    uniform, so lost buckets get s=0 for planning; reported plan cost is the
    *network* migration cost among survivors — checkpoint read bytes are
    reported separately by the caller)."""
    s_eff = np.asarray(s, dtype=np.float64).copy()
    owner = old.owner_of()
    for nid in failed:
        s_eff[owner == nid] = 0.0
    plan = ssm(old, n_new, w, s_eff, tau)
    # relabel: dead nodes may only hold zero-gain intervals — move them to
    # free alive slots.
    ivs = list(plan.new.intervals)
    n_total = len(ivs)
    used_alive = {i for i, iv in enumerate(ivs)
                  if iv[1] > iv[0] and i not in failed}
    for nid in sorted(failed):
        iv = ivs[nid]
        if iv[1] <= iv[0]:
            continue
        # find a free alive slot
        tgt = next(i for i in range(n_total)
                   if i not in failed and i not in used_alive
                   and ivs[i][1] <= ivs[i][0])
        ivs[tgt] = iv
        ivs[nid] = (old.m, old.m)
        used_alive.add(tgt)
    new = Assignment(old.m, tuple(ivs))
    return _plan(old, new, s_eff)


def restored_bytes(old: Assignment, failed: Set[int], s: np.ndarray) -> float:
    """Checkpoint bytes that must be read back regardless of strategy."""
    owner = old.owner_of()
    s = np.asarray(s, dtype=np.float64)
    return float(sum(s[owner == nid].sum() for nid in failed))


# ---------------------------------------------------------------------------
# Stragglers
# ---------------------------------------------------------------------------

@dataclass
class SpeedTracker:
    """EWMA per-node step times -> relative speeds + straggler detection."""

    n_nodes: int
    alpha: float = 0.3
    threshold: float = 1.5          # straggler: slower than 1.5× median

    def __post_init__(self):
        self.ewma = np.zeros(self.n_nodes)

    def update(self, step_times: Sequence[float]) -> None:
        t = np.asarray(step_times, dtype=np.float64)
        if t.shape != self.ewma.shape:
            raise ValueError(
                f"got {t.shape[0] if t.ndim else 0} step times for "
                f"{len(self.ewma)} tracked nodes — resize() the tracker "
                "when the topology changes")
        self.ewma = np.where(self.ewma == 0, t,
                             self.alpha * t + (1 - self.alpha) * self.ewma)

    def resize(self, n_new: int,
               keep: Optional[Sequence[int]] = None) -> None:
        """Resize to ``n_new`` node slots after a topology change.

        EWMAs of node ids in ``keep`` (default: every id present both
        before and after) survive; new or vacated slots reset to 0, which
        ``speeds``/``stragglers`` treat as "no observation yet"."""
        new = np.zeros(n_new)
        ids = range(min(len(self.ewma), n_new)) if keep is None else keep
        for i in ids:
            if 0 <= i < n_new and i < len(self.ewma):
                new[i] = self.ewma[i]
        self.ewma = new
        self.n_nodes = n_new

    def speeds(self) -> np.ndarray:
        t = np.where(self.ewma <= 0, np.median(self.ewma[self.ewma > 0])
                     if (self.ewma > 0).any() else 1.0, self.ewma)
        return (1.0 / t) / (1.0 / t).max()

    def stragglers(self) -> List[int]:
        med = np.median(self.ewma[self.ewma > 0]) if (self.ewma > 0).any() \
            else 0.0
        return [i for i, t in enumerate(self.ewma)
                if med > 0 and t > self.threshold * med]


def weighted_plan(old: Assignment, speeds: Sequence[float],
                  w: np.ndarray, s: np.ndarray, tau: float,
                  granularity: int = 4
                  ) -> Tuple[MigrationPlan, List[List[int]]]:
    """SSM with per-node speed weights via virtual slots.

    Returns (plan over physical nodes, virtual→physical map used).  Virtual
    slots belonging to one physical node receive disjoint intervals; the
    physical node's load is their sum, ≤ (1+τ)·W·slots_i/Σslots ≈ the
    weighted budget.  The plan's ``new`` assignment is over *virtual* slots;
    callers project it with ``collapse_virtual``.
    """
    speeds = np.asarray(speeds, dtype=np.float64)
    n = old.n_nodes
    slots = np.maximum(1, np.round(speeds * granularity).astype(int))
    # virtual old assignment: physical node i's interval is split evenly
    # among its slots (zero-cost relabeling within a node: same machine)
    v_ivs: List[Tuple[int, int]] = []
    v_of: List[int] = []                     # virtual -> physical
    for i, (lo, hi) in enumerate(old.intervals):
        k = slots[i] if hi > lo else 1
        if hi <= lo:
            v_ivs.append((old.m, old.m))
            v_of.append(i)
            continue
        cuts = np.linspace(lo, hi, k + 1).round().astype(int)
        for j in range(k):
            v_ivs.append((int(cuts[j]), int(cuts[j + 1])))
            v_of.append(i)
    v_old = Assignment(old.m, tuple(v_ivs))
    v_plan = ssm(v_old, len(v_ivs), w, s, tau)
    phys_map: List[List[int]] = [[] for _ in range(n)]
    for v, p in enumerate(v_of):
        phys_map[p].append(v)
    return v_plan, phys_map


def collapse_virtual(v_plan: MigrationPlan, v_of: Sequence[int],
                     n_physical: int, s: np.ndarray,
                     old_physical: Assignment) -> Dict[int, List[Tuple[int, int]]]:
    """Project a virtual-slot plan to physical ownership: node -> interval
    list (possibly >1 contiguous runs)."""
    out: Dict[int, List[Tuple[int, int]]] = {i: [] for i in range(n_physical)}
    for v, iv in enumerate(v_plan.new.intervals):
        if iv[1] > iv[0]:
            p = v_of[v] if v < len(v_of) else v % n_physical
            out[p].append(iv)
    return out


def physical_migration_cost(v_plan: MigrationPlan, v_of: Sequence[int],
                            s: np.ndarray) -> float:
    """Bytes crossing *physical* machine boundaries (virtual moves within a
    node are free)."""
    s = np.asarray(s, dtype=np.float64)
    n_v = max(v_plan.old.n_nodes, v_plan.new.n_nodes)
    old_o = v_plan.old.padded(n_v).owner_of()
    new_o = v_plan.new.padded(n_v).owner_of()
    vof = list(v_of) + [(-1)] * (n_v - len(v_of))
    cost = 0.0
    for j in range(v_plan.old.m):
        po = vof[old_o[j]] if old_o[j] < len(vof) else -1
        pn = vof[new_o[j]] if new_o[j] < len(vof) else -2
        if po != pn:
            cost += s[j]
    return float(cost)
