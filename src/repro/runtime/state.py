"""Bucketed operator state (paper §2: tasks and their states).

The unit of migration is a *bucket* (the paper's task): a pytree whose
leaves all share a leading bucket axis of size m.  Concrete operator states
in this framework:

* serving: per-bucket KV/recurrent state of the requests hashed there
* streaming quickstart: per-bucket aggregation counters (word counts)
* training: per-bucket optimizer-state slices (ZeRO resharding on elastic
  events)

``bucket_bytes`` drives the planner's |s_j|; ``route`` is the paper's
partitioning function f(r) (cheap hash → bucket id); nodes own contiguous
bucket intervals so the routing table is just the interval boundaries
(paper §2.1's CPU-cache argument → here a tiny (n+1,) int array).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

try:  # jax is optional at this layer: the sim backend is pure numpy
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None


@dataclass
class BucketedState:
    """Host-side view: per-bucket pytrees (list of length m)."""

    buckets: List[Any]                   # bucket id -> pytree (numpy leaves)

    @property
    def m(self) -> int:
        return len(self.buckets)

    def bucket_bytes(self) -> np.ndarray:
        out = np.zeros(self.m)
        for j, b in enumerate(self.buckets):
            leaves = _tree_leaves(b)
            out[j] = float(sum(x.size * x.itemsize for x in leaves))
        return out

    @staticmethod
    def zeros_like_spec(m: int, spec: Dict[str, tuple],
                        dtype=np.float32) -> "BucketedState":
        return BucketedState(
            [{k: np.zeros(shape, dtype) for k, shape in spec.items()}
             for _ in range(m)])


def _tree_leaves(tree) -> List[np.ndarray]:
    if isinstance(tree, dict):
        out: List[np.ndarray] = []
        for v in tree.values():
            out.extend(_tree_leaves(v))
        return out
    if isinstance(tree, (list, tuple)):
        out = []
        for v in tree:
            out.extend(_tree_leaves(v))
        return out
    return [np.asarray(tree)]


def route(keys: np.ndarray, m: int, seed: int = 0) -> np.ndarray:
    """Partitioning function f(r): stable integer hash -> [0, m)."""
    k = np.asarray(keys, dtype=np.uint64)
    s = np.uint64((seed * 0x9E3779B97F4A7C15 + 0xBF58476D1CE4E5B9)
                  % (1 << 64))
    x = (k + s) * np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(29)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(32)
    return (x % np.uint64(m)).astype(np.int64)


def owner_lookup(boundaries: Sequence[int], bucket_ids: np.ndarray
                 ) -> np.ndarray:
    """Interval routing: node = searchsorted(boundaries, bucket) — the whole
    routing table is the boundary array (paper §2.1)."""
    b = np.asarray(boundaries)
    return np.searchsorted(b, np.asarray(bucket_ids), side="right") - 1
