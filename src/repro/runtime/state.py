"""Bucketed operator state (paper §2: tasks and their states).

The unit of migration is a *bucket* (the paper's task): a pytree whose
leaves all share a leading bucket axis of size m.  Concrete operator states
in this framework:

* serving: per-bucket KV/recurrent state of the requests hashed there
* streaming quickstart: per-bucket aggregation counters (word counts)
* training: per-bucket optimizer-state slices (ZeRO resharding on elastic
  events)

``bucket_bytes`` drives the planner's |s_j|; ``route`` is the paper's
partitioning function f(r) (cheap hash → bucket id); nodes own contiguous
bucket intervals so the routing table is just the interval boundaries
(paper §2.1's CPU-cache argument → here a tiny (n+1,) int array).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

try:  # jax is optional at this layer: the sim backend is pure numpy
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None


@dataclass
class BucketedState:
    """Host-side view: per-bucket pytrees (list of length m)."""

    buckets: List[Any]                   # bucket id -> pytree (numpy leaves)

    @property
    def m(self) -> int:
        return len(self.buckets)

    def bucket_bytes(self) -> np.ndarray:
        out = np.zeros(self.m)
        for j, b in enumerate(self.buckets):
            leaves = _tree_leaves(b)
            out[j] = float(sum(x.size * x.itemsize for x in leaves))
        return out

    @staticmethod
    def zeros_like_spec(m: int, spec: Dict[str, tuple],
                        dtype=np.float32) -> "BucketedState":
        return BucketedState(
            [{k: np.zeros(shape, dtype) for k, shape in spec.items()}
             for _ in range(m)])


def _tree_leaves(tree) -> List[np.ndarray]:
    if isinstance(tree, dict):
        out: List[np.ndarray] = []
        for v in tree.values():
            out.extend(_tree_leaves(v))
        return out
    if isinstance(tree, (list, tuple)):
        out = []
        for v in tree:
            out.extend(_tree_leaves(v))
        return out
    return [np.asarray(tree)]


# ---------------------------------------------------------------------------
# Device-resident bucketed state: the REAL decode cache as operator state
# ---------------------------------------------------------------------------

def cache_batch_axis(names: Sequence[str]) -> int:
    """Which axis of a decode-cache leaf is the *request* (batch) axis.

    ``init_cache`` stacks the repeated-pattern layer groups (``blocks``) and
    the encoder-decoder cross K/V with a leading layer axis, so their batch
    axis is 1; ``tail`` (and any unstacked) leaves carry batch at axis 0.
    ``names`` is the leaf's key path from the cache root.  This is the rule
    serve.py's old ``per_req = prod(shape[1:])`` estimate got wrong: it
    priced every leaf as if axis 0 were batch, so stacked leaves were
    divided by the layer count instead of multiplied by it.
    """
    return 1 if names and names[0] in ("blocks", "cross_k", "cross_v") else 0


def _key_path_names(path) -> List[str]:
    return [str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path]


def cache_batch_axes(cache) -> Any:
    """Pytree of ints matching ``cache``: the request axis of every leaf."""
    if jax is None:  # pragma: no cover
        raise RuntimeError("cache_batch_axes requires jax")
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_batch_axis(_key_path_names(path)), cache)


class DeviceBucketedState:
    """Bucketed view whose leaves ARE the live jax decode cache.

    Serving nodes are modelled as separate device buffers: node ``i`` holds
    a cache shard whose request axis has a fixed row capacity ``cap``
    (padded rows are inert — decode on them is masked out by the caller).
    A request's KV/recurrent rows live in exactly one node's shard, located
    by ``req_node``/``req_row``; migration physically copies those rows
    between shards (true device-to-device transfers when nodes map to
    distinct jax devices, plain buffer copies on a single device).

    Satisfies the ``bucket_bytes()`` protocol of ``MigrationExecutor``, so
    the SSM planner prices buckets from the *actual* leaf shapes/dtypes:
    per-request bytes = Σ_leaf nbytes / cap (the request axis is ``cap`` in
    every shard leaf), bucket j = per-request bytes × #requests hashed to j.
    """

    def __init__(self, shards: Dict[int, Any], row_req: Dict[int, np.ndarray],
                 req_bucket: np.ndarray, m: int, cap: int,
                 devices: Optional[Sequence] = None):
        self.shards = shards                  # node id -> cache pytree
        self.row_req = row_req                # node id -> int[cap], -1 free
        self.req_bucket = np.asarray(req_bucket)
        self._m = int(m)
        self.cap = int(cap)
        self.devices = list(devices) if devices else None
        B = len(self.req_bucket)
        self.req_node = np.full(B, -1, np.int64)
        self.req_row = np.full(B, -1, np.int64)
        for i, rr in row_req.items():
            valid = rr >= 0
            self.req_node[rr[valid]] = i
            self.req_row[rr[valid]] = np.nonzero(valid)[0]
        tpl = next(iter(shards.values()))
        self._axes = cache_batch_axes(tpl)
        self.row_nbytes = float(sum(
            leaf.size * leaf.dtype.itemsize / self.cap
            for leaf in jax.tree_util.tree_leaves(tpl)))

    # -- construction -------------------------------------------------------
    @classmethod
    def from_cache(cls, cache, req_bucket: np.ndarray, owner: np.ndarray,
                   cap: Optional[int] = None,
                   devices: Optional[Sequence] = None
                   ) -> "DeviceBucketedState":
        """Split a global [B, ...]-batched cache into per-node shards.

        ``owner``: bucket id -> node id (``Assignment.owner_of()``); rows
        are laid out bucket-major inside each shard so a node's buckets are
        contiguous row runs (the paper's interval layout)."""
        req_bucket = np.asarray(req_bucket)
        B = len(req_bucket)
        cap = int(cap or B)
        axes = cache_batch_axes(cache)
        node_of_req = np.asarray(owner)[req_bucket]
        shards: Dict[int, Any] = {}
        row_req: Dict[int, np.ndarray] = {}
        for i in sorted(set(int(n) for n in node_of_req)):
            reqs = np.nonzero(node_of_req == i)[0]
            reqs = reqs[np.argsort(req_bucket[reqs], kind="stable")]
            if len(reqs) > cap:
                raise ValueError(f"node {i}: {len(reqs)} rows > cap {cap}")
            shard = jax.tree_util.tree_map(
                lambda leaf, ax: _pad_rows(
                    jnp.take(leaf, jnp.asarray(reqs), axis=ax), ax, cap),
                cache, axes)
            if devices:
                shard = jax.device_put(shard, devices[i % len(devices)])
            shards[i] = shard
            rr = np.full(cap, -1, np.int64)
            rr[: len(reqs)] = reqs
            row_req[i] = rr
        return cls(shards, row_req, req_bucket, len(np.asarray(owner)),
                   cap, devices=devices)

    # -- bucketed-state protocol -------------------------------------------
    @property
    def m(self) -> int:
        return self._m

    def bucket_bytes(self) -> np.ndarray:
        counts = np.bincount(self.req_bucket, minlength=self._m)
        return counts.astype(np.float64) * self.row_nbytes

    # -- accessors ----------------------------------------------------------
    def node_ids(self) -> List[int]:
        return sorted(self.shards)

    def device_of(self, node: int):
        if not self.devices:
            return None
        return self.devices[node % len(self.devices)]

    def bucket_requests(self, j: int) -> np.ndarray:
        return np.nonzero(self.req_bucket == j)[0]

    def _ensure_node(self, i: int) -> None:
        if i in self.shards:
            return
        tpl = next(iter(self.shards.values()))
        shard = jax.tree_util.tree_map(jnp.zeros_like, tpl)
        if self.devices:
            shard = jax.device_put(shard, self.device_of(i))
        self.shards[i] = shard
        self.row_req[i] = np.full(self.cap, -1, np.int64)

    # -- migration ----------------------------------------------------------
    def run_phase(self, phase: Sequence) -> float:
        """Physically execute one phase of bucket moves: for every
        (src, dst) pair, gather the moving buckets' request rows from the
        source shard, transfer them, and scatter into free rows of the
        destination shard.  Returns the bytes actually moved (from real
        leaf shapes)."""
        by_pair: Dict[tuple, List[int]] = {}
        for mv in phase:
            by_pair.setdefault((int(mv.src), int(mv.dst)), []).append(
                int(mv.bucket))
        moved = 0.0
        touched = []
        for (src, dst), bkts in sorted(by_pair.items()):
            reqs = np.concatenate([self.bucket_requests(j) for j in bkts])
            if len(reqs) == 0:
                continue
            if not (self.req_node[reqs] == src).all():
                raise RuntimeError(
                    f"buckets {bkts}: rows not on source node {src}")
            self._ensure_node(dst)
            src_rows = jnp.asarray(self.req_row[reqs])
            vals = jax.tree_util.tree_map(
                lambda leaf, ax: jnp.take(leaf, src_rows, axis=ax),
                self.shards[src], self._axes)
            if self.devices:
                vals = jax.device_put(vals, self.device_of(dst))
            free = np.nonzero(self.row_req[dst] < 0)[0][: len(reqs)]
            if len(free) < len(reqs):
                raise RuntimeError(f"node {dst}: out of row capacity "
                                   f"({len(reqs)} in, {len(free)} free)")
            dst_rows = jnp.asarray(free)
            self.shards[dst] = jax.tree_util.tree_map(
                lambda leaf, new, ax: _set_rows(leaf, new, ax, dst_rows),
                self.shards[dst], vals, self._axes)
            self.row_req[src][self.req_row[reqs]] = -1
            self.row_req[dst][free] = reqs
            self.req_node[reqs] = dst
            self.req_row[reqs] = free
            moved += len(reqs) * self.row_nbytes
            touched.append(self.shards[dst])
        if touched:
            jax.block_until_ready(touched)
        return moved

    # -- host views ---------------------------------------------------------
    def gather(self, req_ids: np.ndarray) -> Any:
        """Reassemble the given requests' rows (host-side numpy leaves, in
        request order) — for verification and checkpointing."""
        req_ids = np.asarray(req_ids)
        parts: Dict[int, tuple] = {}
        for i in self.node_ids():
            sel = np.nonzero(np.isin(req_ids, self.row_req[i]))[0]
            if len(sel):
                parts[i] = (sel, self.req_row[req_ids[sel]])
        tpl = next(iter(self.shards.values()))

        def build(path, leaf):
            ax = cache_batch_axis(_key_path_names(path))
            shape = list(leaf.shape)
            shape[ax] = len(req_ids)
            out = np.zeros(shape, leaf.dtype)
            for i, (sel, rows) in parts.items():
                src = np.asarray(_leaf_at(self.shards[i], path))
                idx = [slice(None)] * src.ndim
                idx[ax] = rows
                odx = [slice(None)] * src.ndim
                odx[ax] = sel
                out[tuple(odx)] = src[tuple(idx)]
            return out

        return jax.tree_util.tree_map_with_path(build, tpl)

    def to_host(self) -> "BucketedState":
        """Host BucketedState view: bucket j = its requests' rows (numpy)."""
        return BucketedState(
            [self.gather(self.bucket_requests(j)) for j in range(self._m)])


def _pad_rows(leaf, axis: int, cap: int):
    pad = cap - leaf.shape[axis]
    if pad <= 0:
        return leaf
    widths = [(0, 0)] * leaf.ndim
    widths[axis] = (0, pad)
    return jnp.pad(leaf, widths)


def _set_rows(leaf, new, axis: int, rows):
    idx = (slice(None),) * axis + (rows,)
    return leaf.at[idx].set(new)


def _leaf_at(tree, path):
    node = tree
    for p in path:
        key = getattr(p, "key", getattr(p, "name", getattr(p, "idx", None)))
        node = node[key]
    return node


def route(keys: np.ndarray, m: int, seed: int = 0) -> np.ndarray:
    """Partitioning function f(r): stable integer hash -> [0, m)."""
    k = np.asarray(keys, dtype=np.uint64)
    s = np.uint64((seed * 0x9E3779B97F4A7C15 + 0xBF58476D1CE4E5B9)
                  % (1 << 64))
    x = (k + s) * np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(29)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(32)
    return (x % np.uint64(m)).astype(np.int64)


def owner_lookup(boundaries: Sequence[int], bucket_ids: np.ndarray
                 ) -> np.ndarray:
    """Interval routing: node = searchsorted(boundaries, bucket) — the whole
    routing table is the boundary array (paper §2.1)."""
    b = np.asarray(boundaries)
    return np.searchsorted(b, np.asarray(bucket_ids), side="right") - 1
