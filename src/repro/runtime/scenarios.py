"""Scenario library for closed-loop controller evaluation.

Each scenario packages everything a ``control.ControlLoop`` run needs:
per-interval per-bucket workloads ``w`` and state sizes ``s``, the initial
node count, the per-interval node *budget* (``capacity`` — what the
cluster offers, which the policy may decline to use), and scheduled node
failures.  The catalog covers the situations a production elasticity
controller must not mishandle:

* ``diurnal``        — slow sinusoidal load; a good policy mostly holds.
* ``flash_crowd``    — sudden rate x spike concentrated on a few hot
                       buckets; capacity arrives late, imbalance first.
* ``skew_drift``     — constant total rate, hotspot center drifts across
                       the key space; pure-rebalance territory.
* ``node_loss``      — a node dies right after a scale-up (i.e. while the
                       migration's effects are still settling).
* ``capacity_flap``  — the offered node budget oscillates n <-> n+2 every
                       few intervals; chasing it migrates constantly for
                       nothing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Set

import numpy as np

from repro.data.streaming import BurstyZipfStream, task_state_sizes


@dataclass
class Scenario:
    name: str
    w: np.ndarray                       # [T, m] per-interval bucket loads
    s: np.ndarray                       # [T, m] per-interval state bytes
    n0: int                             # initial node count
    capacity: np.ndarray                # [T] offered node budget
    failures: Dict[int, Set[int]] = field(default_factory=dict)
    description: str = ""

    @property
    def T(self) -> int:
        return int(self.w.shape[0])

    @property
    def m(self) -> int:
        return int(self.w.shape[1])

    @property
    def total_state_bytes(self) -> float:
        """Mean per-interval total state — the normalizer for bytes-moved."""
        return float(self.s.sum(axis=1).mean())


def _zipf_shares(m: int, a: float, rng: np.random.Generator) -> np.ndarray:
    shares = 1.0 / np.arange(1, m + 1) ** a
    rng.shuffle(shares)
    return shares / shares.sum()


def _finish(name: str, w: np.ndarray, s_scale: float, n0: int,
            capacity: np.ndarray, failures=None, description: str = ""
            ) -> Scenario:
    s = task_state_sizes(w) * s_scale
    return Scenario(name=name, w=w, s=s, n0=n0,
                    capacity=capacity.astype(np.int64),
                    failures=failures or {}, description=description)


def diurnal(T: int = 48, m: int = 96, seed: int = 0) -> Scenario:
    """Slow sinusoidal total rate, mild skew, capacity tracks the wave."""
    w = BurstyZipfStream(m_tasks=m, zipf_a=0.9, diurnal_amp=0.5,
                         burst_prob=0.0, seed=seed).intervals(T)
    frac = (w.sum(axis=1) - w.sum(axis=1).min()) / max(
        np.ptp(w.sum(axis=1)), 1e-9)
    cap = np.round(6 + 4 * frac)
    return _finish("diurnal", w, 1.0, int(cap[0]), cap,
                   description="slow daily wave; mostly hold")


def flash_crowd(T: int = 48, m: int = 96, seed: int = 1) -> Scenario:
    """Rate jumps ~5x mid-run and the surge is concentrated on a handful
    of hot buckets, so imbalance spikes before capacity catches up."""
    rng = np.random.default_rng(seed)
    shares = _zipf_shares(m, 1.0, rng)
    hot = np.argsort(shares)[-4:]
    rate = np.full(T, 9_000.0)
    t0, t1 = T // 3, T // 3 + 10
    rate[t0:t1] = 45_000.0
    w = np.zeros((T, m))
    for t in range(T):
        cur = shares.copy()
        if t0 <= t < t1:
            cur[hot] *= 8.0
            cur /= cur.sum()
        w[t] = rng.poisson(rate[t] * cur)
    cap = np.full(T, 6.0)
    cap[t0 + 2:t1 + 4] = 10.0          # ops add nodes two intervals late
    return _finish("flash_crowd", w, 1.0, 6, cap,
                   description="5x surge on 4 hot buckets, capacity late")


def skew_drift(T: int = 48, m: int = 96, seed: int = 2) -> Scenario:
    """Constant total rate; a gaussian hotspot drifts across the key
    space, slowly invalidating any fixed assignment."""
    rng = np.random.default_rng(seed)
    base = _zipf_shares(m, 0.6, rng)
    idx = np.arange(m)
    w = np.zeros((T, m))
    # drift slow enough that a fresh plan stays valid a few intervals
    # (hot topics shift over hours, not minutes) — fast drift degenerates
    # every policy to per-interval replanning
    for t in range(T):
        center = m * (0.3 + 0.4 * t / max(T - 1, 1))
        hot = np.exp(-0.5 * ((idx - center) / (m * 0.10)) ** 2)
        cur = base * (1.0 + 4.0 * hot)
        cur /= cur.sum()
        w[t] = rng.poisson(12_000.0 * cur)
    # a noisy autoscaler offers extra nodes every few intervals; aggregate
    # capacity is rate-proportional, so taking them buys nothing
    cap = np.where((np.arange(T) // 4) % 2 == 0, 8.0, 10.0)
    return _finish("skew_drift", w, 1.0, 8, cap,
                   description="drifting gaussian hotspot, noisy budget")


def node_loss(T: int = 48, m: int = 96, seed: int = 3) -> Scenario:
    """Diurnal load with a scale-up at t0 (capacity step) and a node death
    two intervals later — recovery lands mid-settling."""
    w = BurstyZipfStream(m_tasks=m, zipf_a=1.0, diurnal_amp=0.3,
                         burst_prob=0.1, seed=seed).intervals(T)
    cap = np.full(T, 6.0)
    t0 = T // 2
    cap[t0:] = 9.0
    failures = {t0 + 2: {1}}
    return _finish("node_loss", w, 1.0, 6, cap, failures,
                   description="scale-up then node 1 dies 2 intervals in")


def capacity_flap(T: int = 48, m: int = 96, seed: int = 4) -> Scenario:
    """Steady load but the offered budget oscillates 6 <-> 8 every three
    intervals; following it migrates state back and forth for no gain."""
    w = BurstyZipfStream(m_tasks=m, zipf_a=0.8, diurnal_amp=0.05,
                         burst_prob=0.0, seed=seed).intervals(T)
    cap = np.where((np.arange(T) // 3) % 2 == 0, 6.0, 8.0)
    return _finish("capacity_flap", w, 1.0, 6, cap,
                   description="budget flaps 6<->8; the right move is hold")


SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "diurnal": diurnal,
    "flash_crowd": flash_crowd,
    "skew_drift": skew_drift,
    "node_loss": node_loss,
    "capacity_flap": capacity_flap,
}


def make(name: str, **kw) -> Scenario:
    return SCENARIOS[name](**kw)
