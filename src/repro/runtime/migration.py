"""Migration executor (paper §5): turn a MigrationPlan into scheduled bucket
moves and run them — suspended, live, or progressive.

* ``move_list``        — diff two assignments into per-bucket moves.
* ``schedule_phases``  — Rödiger et al. [27]-style phase construction: pack
                         moves into phases so every node's uplink and
                         downlink bytes per phase are balanced; total time
                         ≈ Σ_phase max_node(bytes)/BW instead of Σ all bytes
                         through one bottleneck link.
* ``schedule_rounds``  — Megaphone-style conflict-free parallel rounds:
                         each round is a maximum bipartite matching
                         (``hopcroft_karp``) over links with pending moves,
                         so every node sends at most one bucket batch and
                         receives at most one per round; ``round_windows``
                         turns the rounds into per-bucket pause windows
                         where a bucket stops only for its own transfer.
* ``SimBackend``       — byte/clock accounting (benchmarks fig8/fig11).
* ``JaxBackend``       — executes phases on REAL jax state, wall-clock
                         measured: row-level cache resharding for
                         ``DeviceBucketedState`` (the live serving path),
                         whole-bucket device_put for host pytrees.
* ``make_migration_step`` — a jit-able resharding step for the dry run:
                         uniform-bucket state [m, ...] sharded over the
                         elastic axis migrates via gather, which XLA lowers
                         to all-to-all/collective-permute; its HLO collective
                         bytes are compared against the planner's predicted
                         cost in benchmarks/migration_dryrun.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import Assignment, MigrationPlan
from .state import BucketedState


@dataclass(frozen=True)
class Move:
    bucket: int
    src: int
    dst: int
    nbytes: float


def move_list(plan: MigrationPlan, bucket_bytes: np.ndarray) -> List[Move]:
    old_owner = plan.old.owner_of()
    n_total = max(plan.old.n_nodes, plan.new.n_nodes)
    new_owner = plan.new.padded(n_total).owner_of()
    out: List[Move] = []
    for j in range(plan.old.m):
        if old_owner[j] != new_owner[j]:
            out.append(Move(j, int(old_owner[j]), int(new_owner[j]),
                            float(bucket_bytes[j])))
    return out


def schedule_phases(moves: Sequence[Move],
                    phase_budget: Optional[float] = None
                    ) -> List[List[Move]]:
    """Greedy phase packing balancing per-node up/down bytes.

    ``phase_budget`` defaults to total bytes / #endpoints (so phases are few
    but per-node balanced); pass a smaller budget (progressive mode) to
    bound simultaneously-suspended buckets.  Each phase admits a move iff
    both endpoints stay within budget; always ≥1 move per phase.
    """
    if not moves:
        return []
    max_move = max(m.nbytes for m in moves)
    if phase_budget is None:
        endpoints = {m.src for m in moves} | {m.dst for m in moves}
        total = sum(m.nbytes for m in moves)
        phase_budget = total / max(len(endpoints), 1)
    budget = max(phase_budget, max_move)
    remaining = sorted(moves, key=lambda m: -m.nbytes)
    phases: List[List[Move]] = []
    while remaining:
        up: Dict[int, float] = {}
        down: Dict[int, float] = {}
        phase: List[Move] = []
        rest: List[Move] = []
        for mv in remaining:
            if (up.get(mv.src, 0.0) + mv.nbytes <= budget
                    and down.get(mv.dst, 0.0) + mv.nbytes <= budget):
                phase.append(mv)
                up[mv.src] = up.get(mv.src, 0.0) + mv.nbytes
                down[mv.dst] = down.get(mv.dst, 0.0) + mv.nbytes
            else:
                rest.append(mv)
        if not phase:  # can't happen (budget >= max move), but stay safe
            phase, rest = [rest[0]], rest[1:]
        phases.append(phase)
        remaining = rest
    return phases


def phase_duration(phase: Sequence[Move], bw_bytes_per_s: float) -> float:
    """A phase completes when the busiest link finishes (full-duplex)."""
    up: Dict[int, float] = {}
    down: Dict[int, float] = {}
    for mv in phase:
        up[mv.src] = up.get(mv.src, 0.0) + mv.nbytes
        down[mv.dst] = down.get(mv.dst, 0.0) + mv.nbytes
    worst = max(list(up.values()) + list(down.values()) + [0.0])
    return worst / bw_bytes_per_s


def naive_duration(moves: Sequence[Move], bw_bytes_per_s: float) -> float:
    """Unscheduled baseline: the busiest node serializes ALL its traffic and
    transfers run sequentially per node pair (kill-restart style restore)."""
    total = sum(m.nbytes for m in moves)
    return total / bw_bytes_per_s


def fluid_budget(bucket_bytes: np.ndarray, batch: int) -> float:
    """Phase budget for Megaphone-style fluid migration: at most ``batch``
    buckets' worth of bytes in flight per node per phase.  batch=1 is pure
    fluid (each bucket's pause ≈ its own transfer); large batches recover
    live migration's single bulk phase; batch=max_inflight matches the
    progressive mode."""
    mx = float(bucket_bytes.max()) if len(bucket_bytes) else 1.0
    return max(batch, 1) * mx


def strategy_schedule(moves: Sequence[Move], bucket_bytes: np.ndarray,
                      mode: str, max_inflight: int = 4,
                      fluid_batch: int = 1) -> List[List[Move]]:
    """The phase/round structure strategy ``mode`` executes — the single
    dispatch shared by ``MigrationExecutor``, ``serving.strategy_windows``
    and ``analysis.plancheck``, so the verifier always checks exactly the
    schedule the runtime runs (no checker/executor drift).

    suspend / kill_restart → one bulk transfer; progressive → phases with
    ``max_inflight`` buckets' budget per node; fluid → ``fluid_budget``
    phases; batched_fluid → Hopcroft–Karp matching rounds; live → default
    balanced phases.
    """
    if not moves:
        return []
    bb = np.asarray(bucket_bytes, dtype=np.float64)
    if mode in ("suspend", "kill_restart"):
        return [list(moves)]
    if mode == "batched_fluid":
        return schedule_rounds(moves, batch=fluid_batch)
    if mode == "progressive":
        budget = max_inflight * (float(bb.max()) if len(bb) else 1.0)
        return schedule_phases(moves, phase_budget=budget)
    if mode == "fluid":
        return schedule_phases(moves,
                               phase_budget=fluid_budget(bb, fluid_batch))
    return schedule_phases(moves)                 # live


def bucket_windows(phases: Sequence[Sequence[Move]], bw_bytes_per_s: float,
                   m: int, fluid: bool = False, sync_s: float = 0.0
                   ) -> Tuple[np.ndarray, np.ndarray, float]:
    """Per-bucket unavailability windows [from, until) implied by running the
    phases back-to-back, plus the total migration duration.

    With ``fluid=False`` (paper §5.2 live/progressive semantics) a moving
    bucket stops at its old owner when the migration *begins*, so its window
    opens at 0 and closes when its phase lands.  With ``fluid=True``
    (Megaphone, Hoffmann et al. 1812.01371) a bucket keeps processing until
    its own phase starts: the window is exactly its phase's [start, end).

    ``sync_s`` is the per-phase coordination cost (the routing-table update
    every node must apply before the next phase may start — §5.2's routing
    table, Megaphone's reconfiguration broadcast).  It extends the clock
    after every phase (including the last: the final update still has to
    propagate) but pauses no bucket — tuples routed with a stale table are
    forwarded, which the simulators charge separately.
    """
    un_from = np.zeros(m)
    un_until = np.zeros(m)
    clock = 0.0
    for ph in phases:
        dur = phase_duration(ph, bw_bytes_per_s)
        for mv in ph:
            un_from[mv.bucket] = clock if fluid else 0.0
            un_until[mv.bucket] = clock + dur
        clock += dur + sync_s
    return un_from, un_until, clock


# ---------------------------------------------------------------------------
# Batched-fluid rounds (Megaphone: conflict-free parallel mini-migrations)
# ---------------------------------------------------------------------------

def hopcroft_karp(adj: Dict[int, Sequence[int]]) -> Dict[int, int]:
    """Maximum bipartite matching, O(E·√V) (Hopcroft–Karp, pure python).

    ``adj`` maps left vertices (sender node ids) to the right vertices
    (receiver node ids) they have an edge to; the two sides are separate
    namespaces, so a node acting as both sender and receiver may appear on
    both sides under the same id.  Returns the left→right matching as a
    dict.  Deterministic: vertices are scanned in sorted order, so runs are
    reproducible and the simulators' differential tests stay exact.
    """
    from collections import deque

    INF = float("inf")
    left = sorted(adj)
    edges = {u: sorted(set(adj[u])) for u in left}
    match_l: Dict[int, Optional[int]] = {u: None for u in left}
    match_r: Dict[int, Optional[int]] = {}
    dist: Dict[int, float] = {}

    def bfs() -> bool:
        q = deque()
        for u in left:
            if match_l[u] is None:
                dist[u] = 0.0
                q.append(u)
            else:
                dist[u] = INF
        found = False
        while q:
            u = q.popleft()
            for v in edges[u]:
                w = match_r.get(v)
                if w is None:
                    found = True
                elif dist[w] == INF:
                    dist[w] = dist[u] + 1.0
                    q.append(w)
        return found

    def dfs(u: int) -> bool:
        for v in edges[u]:
            w = match_r.get(v)
            if w is None or (dist[w] == dist[u] + 1.0 and dfs(w)):
                match_l[u] = v
                match_r[v] = u
                return True
        dist[u] = INF
        return False

    while bfs():
        for u in left:
            if match_l[u] is None:
                dfs(u)
    return {u: v for u, v in match_l.items() if v is not None}


def schedule_rounds(moves: Sequence[Move], batch: int = 1
                    ) -> List[List[Move]]:
    """Conflict-free parallel rounds (Megaphone's batched migration).

    Group the moves by directed link (src, dst); while any link has pending
    buckets, build a maximum matching over those links with Hopcroft–Karp
    and let every matched link ship one *bucket batch* that round: its
    largest pending buckets up to ``batch · max(bucket bytes)`` bytes
    (always at least one) — the same per-node in-flight budget
    ``fluid_budget`` gives the fluid strategy, so the two knobs are
    directly comparable.  Each node sends at most one batch and receives
    at most one per round; no two links share an endpoint, so every
    transfer in a round proceeds at full per-link bandwidth and the round
    lasts exactly as long as its slowest link.

    Compared to ``schedule_phases`` (greedy per-node byte packing), the
    matching keeps every movable node busy every round and the batch
    amortizes the per-round coordination barrier
    (``SimConfig.phase_sync_s``) that per-bucket fluid pays once per
    phase.  Rounds cover exactly ``moves``: no bucket dropped or shipped
    twice.
    """
    if not moves:
        return []
    cap = max(int(batch), 1) * max(mv.nbytes for mv in moves)
    pending: Dict[Tuple[int, int], List[Move]] = {}
    for mv in moves:
        pending.setdefault((mv.src, mv.dst), []).append(mv)
    for q in pending.values():
        q.sort(key=lambda mv: (-mv.nbytes, mv.bucket))
    rounds: List[List[Move]] = []
    while pending:
        adj: Dict[int, List[int]] = {}
        for src, dst in pending:
            adj.setdefault(src, []).append(dst)
        matching = hopcroft_karp(adj)
        rnd: List[Move] = []
        for src in sorted(matching):
            link = (src, matching[src])
            q = pending[link]
            take, sent = 1, q[0].nbytes          # ≥ 1 move per round
            while take < len(q) and sent + q[take].nbytes <= cap:
                sent += q[take].nbytes
                take += 1
            rnd.extend(q[:take])
            del q[:take]
            if not q:
                del pending[link]
        rounds.append(rnd)    # matching is non-empty while moves pend
    return rounds


def round_windows(rounds: Sequence[Sequence[Move]], bw_bytes_per_s: float,
                  m: int, sync_s: float = 0.0
                  ) -> Tuple[np.ndarray, np.ndarray, float]:
    """Per-bucket pause windows [start, end) for batched-fluid rounds.

    Within a round every matched link ships its batch *sequentially*, so a
    bucket is paused exactly for its own transfer (``nbytes``/BW) — the
    fluid guarantee survives batching.  The round barrier advances the
    clock by the slowest link's total plus ``sync_s`` (the routing-table
    update between rounds; see ``bucket_windows``).  Returns
    (pause_start[m], pause_end[m], total migration duration).
    """
    un_from = np.zeros(m)
    un_until = np.zeros(m)
    clock = 0.0
    for rnd in rounds:
        link_t: Dict[Tuple[int, int], float] = {}
        dur = 0.0
        for mv in rnd:
            off = link_t.get((mv.src, mv.dst), 0.0)
            t = mv.nbytes / bw_bytes_per_s
            un_from[mv.bucket] = clock + off
            un_until[mv.bucket] = clock + off + t
            link_t[(mv.src, mv.dst)] = off + t
            dur = max(dur, off + t)
        clock += dur + sync_s
    return un_from, un_until, clock


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class SimBackend:
    """Accounting backend: tracks bytes moved and a simulated clock."""

    def __init__(self, bw_bytes_per_s: float = 1e9):
        self.bw = bw_bytes_per_s
        self.clock = 0.0
        self.bytes_moved = 0.0
        self.phase_log: List[Tuple[float, float]] = []   # (start, end)

    def run_phase(self, phase: Sequence[Move], state: BucketedState,
                  placement: np.ndarray):
        dur = phase_duration(phase, self.bw)
        start = self.clock
        self.clock += dur
        for mv in phase:
            placement[mv.bucket] = mv.dst
            self.bytes_moved += mv.nbytes
        self.phase_log.append((start, self.clock))


class JaxBackend:
    """Executes migration phases on REAL jax state, wall-clock measured.

    Two state layouts are supported:

    * ``DeviceBucketedState`` (runtime.state) — the live decode cache held
      as per-node device shards.  Each phase delegates to
      ``state.run_phase``: the moving buckets' request rows are gathered
      from the source shards, transferred (device-to-device when nodes map
      to distinct jax devices), and scattered into the destination shards.
      Bytes moved come from the actual leaf shapes/dtypes.
    * host ``BucketedState`` — legacy: whole bucket pytrees are
      ``device_put`` to the destination node's device.

    Same accounting protocol as ``SimBackend`` (``clock`` / ``bytes_moved``
    / ``phase_log``), except the clock advances by *measured* seconds
    (``block_until_ready`` around each phase).  ``bw`` is only the
    denominator of the executor's naive-baseline estimate.
    """

    def __init__(self, devices=None, bw_bytes_per_s: float = 1e9):
        import jax
        self.devices = list(devices) if devices is not None else jax.devices()
        self.bw = bw_bytes_per_s
        self.clock = 0.0
        self.bytes_moved = 0.0
        self.phase_log: List[Tuple[float, float]] = []

    def run_phase(self, phase: Sequence[Move], state,
                  placement: np.ndarray):
        import time as _time

        import jax
        # JaxBackend's whole point is a *measured* clock (docstring above):
        # the wall time is reported, never fed back into planning
        t0 = _time.perf_counter()   # jaxlint: disable=JAX005
        if hasattr(state, "run_phase"):       # device-resident bucketed view
            nbytes = state.run_phase(phase)
        else:                                  # host bucket pytrees
            nbytes = 0.0
            moved = []
            for mv in phase:
                dev = self.devices[mv.dst % len(self.devices)]
                state.buckets[mv.bucket] = jax.device_put(
                    state.buckets[mv.bucket], dev)
                moved.append(state.buckets[mv.bucket])
                nbytes += mv.nbytes
            if moved:
                jax.block_until_ready(moved)
        dt = _time.perf_counter() - t0   # jaxlint: disable=JAX005
        for mv in phase:
            placement[mv.bucket] = mv.dst
        start = self.clock
        self.clock += dt
        self.bytes_moved += nbytes
        self.phase_log.append((start, self.clock))


@dataclass
class MigrationReport:
    moves: int
    bytes_moved: float
    phases: int
    duration_s: float
    naive_duration_s: float
    suspended_peak: int          # max simultaneously-suspended buckets/node
    # busiest-link bytes of each executed phase: the roofline input for
    # predicting transfer time on a target interconnect
    # (roofline.migration_transfer_s)
    phase_link_bytes: List[float] = field(default_factory=list)


class MigrationExecutor:
    """Executes a MigrationPlan over a backend.

    mode:
      suspend     — everything moves in one go; app paused for the duration
                    (paper §5.1 without restart).
      live        — app keeps running; move-in buckets are suspended only
                    until their phase lands (paper §5.2).
      progressive — live + mini-migrations: at most ``max_inflight`` move-in
                    buckets per node at a time (paper §5.2 last ¶).
      fluid       — Megaphone-style per-bucket sequencing: ``fluid_batch``
                    buckets per node per phase (default 1), each bucket
                    paused only for its own transfer window.
      batched_fluid — Megaphone's batched variant: conflict-free parallel
                    rounds (``schedule_rounds``, Hopcroft–Karp matching);
                    each node sends/receives at most one ``fluid_batch``-
                    bucket batch per round, each bucket paused only for its
                    own transfer.
      kill_restart— alias of suspend (full stop; the serving simulators
                    additionally charge the restart overhead).

    verify: None (default) skips checking; "warn" runs the
      ``analysis.plancheck`` rule catalog on every plan+schedule before
      executing and prints findings to stderr; "strict" raises
      ``PlanVerificationError`` instead — nothing runs on a bad plan.
    """

    MODES = ("suspend", "kill_restart", "live", "progressive", "fluid",
             "batched_fluid")
    VERIFY_LEVELS = (None, "warn", "strict")

    def __init__(self, backend=None, mode: str = "live",
                 max_inflight: int = 4, fluid_batch: int = 1,
                 verify: Optional[str] = None):
        if mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, "
                             f"got {mode!r}")
        if verify not in self.VERIFY_LEVELS:
            raise ValueError(f"verify must be one of {self.VERIFY_LEVELS}, "
                             f"got {verify!r}")
        self.backend = backend or SimBackend()
        self.mode = mode
        self.max_inflight = max_inflight
        self.fluid_batch = fluid_batch
        self.verify = verify

    def _verify(self, plan: MigrationPlan, bb: np.ndarray,
                moves: Sequence[Move],
                phases: Sequence[Sequence[Move]]) -> None:
        # lazy import: analysis imports this module at load time
        from repro.analysis import plancheck
        findings = plancheck.check_plan(plan, bb)
        findings += plancheck.check_moves(plan, bb, moves)
        findings += plancheck.check_schedule(moves, phases, self.mode)
        findings += plancheck.check_permutation(plan)
        plancheck.handle(findings, self.verify,
                         where=f"MigrationExecutor[{self.mode}]")

    def execute(self, plan: MigrationPlan, state: BucketedState,
                placement: np.ndarray) -> MigrationReport:
        bb = state.bucket_bytes()
        moves = move_list(plan, bb)
        phases = strategy_schedule(moves, bb, self.mode,
                                   max_inflight=self.max_inflight,
                                   fluid_batch=self.fluid_batch)
        if self.verify:
            self._verify(plan, bb, moves, phases)
        t0 = getattr(self.backend, "clock", 0.0)
        for phase in phases:
            self.backend.run_phase(phase, state, placement)
        t1 = getattr(self.backend, "clock", 0.0)
        bw = getattr(self.backend, "bw", 1e9)
        peak = 0
        for phase in phases:
            per_node: Dict[int, int] = {}
            for mv in phase:
                per_node[mv.dst] = per_node.get(mv.dst, 0) + 1
            if per_node:
                peak = max(peak, max(per_node.values()))
        return MigrationReport(
            moves=len(moves),
            bytes_moved=float(sum(m.nbytes for m in moves)),
            phases=len(phases),
            duration_s=t1 - t0,
            naive_duration_s=naive_duration(moves, bw),
            suspended_peak=peak,
            phase_link_bytes=[phase_duration(ph, 1.0) for ph in phases],
        )


# ---------------------------------------------------------------------------
# Dry-run migration step (uniform buckets, jit + GSPMD)
# ---------------------------------------------------------------------------

def make_migration_step(m: int):
    """Returns step(state, perm) -> state[perm]: uniform-bucket resharding.

    NOTE: with a *dynamic* perm GSPMD cannot see the communication pattern
    and conservatively all-gathers the whole state — measured in
    benchmarks/migration_dryrun.py as the naive baseline.  The plan-aware
    program is ``make_collective_migration`` below.
    """
    import jax.numpy as jnp

    def migration_step(state, perm):
        return jnp.take(state, perm, axis=0)

    return migration_step


def required_capacity(plan: MigrationPlan) -> int:
    """Max bucket slots any device needs: staying buckets keep their OLD
    slot index, so the requirement is max(old slot index of stayers)+1 or
    the post-migration bucket count, whichever is larger."""
    n_total = max(plan.old.n_nodes, plan.new.n_nodes)
    old_p, new_p = plan.old.padded(n_total), plan.new.padded(n_total)
    old_o, new_o = old_p.owner_of(), new_p.owner_of()
    m = plan.old.m
    old_slot = np.zeros(m, np.int64)
    for i, (lo, hi) in enumerate(old_p.intervals):
        old_slot[lo:hi] = np.arange(hi - lo)
    need = 1
    for d in range(n_total):
        stay_max = max((int(old_slot[j]) + 1 for j in range(m)
                        if old_o[j] == d and new_o[j] == d), default=0)
        count = int((new_o == d).sum())
        incoming = int(((new_o == d) & (old_o != d)).sum())
        need = max(need, stay_max + incoming, count)
    return need


def make_collective_migration(plan: MigrationPlan, n_devices: int,
                              cap: int, axis: str = "data"):
    """Compile the migration plan into a static sequence of phased
    ``lax.ppermute``s — the TPU-fabric version of the paper's §5 executor.

    State layout: [n_devices, cap, chunk] — device i holds its buckets in
    slots [0, cap).  Host-side slot maps are derived from the plan's
    interval assignments (bucket j of node i sits in slot j − lb_i).  Each
    Rödiger phase admits ≤1 outgoing and ≤1 incoming bucket per device and
    becomes ONE collective-permute whose per-device payload is the slot it
    sends that phase — so the emitted HLO moves exactly the bytes the
    planner predicted (benchmarks/migration_dryrun.py asserts this).

    Returns (fn, n_phases) where fn maps state [n, cap, chunk] -> state
    with moved buckets landed in destination slots (run under jit with the
    state sharded over ``axis``; requires a mesh with that axis in scope).
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    n_total = max(plan.old.n_nodes, plan.new.n_nodes)
    old_o = plan.old.padded(n_total).owner_of()
    new_p = plan.new.padded(n_total)
    new_o = new_p.owner_of()
    m = plan.old.m
    old_slot = np.zeros(m, np.int64)
    for i, (lo, hi) in enumerate(plan.old.padded(n_total).intervals):
        old_slot[lo:hi] = np.arange(hi - lo)
    # "to stay" buckets keep their slot (they never move — paper §5.1);
    # "to move in" buckets take slots freed on the destination.
    need = required_capacity(plan)
    if cap < need:
        raise ValueError(f"slot capacity {cap} < required {need}")
    new_slot = old_slot.copy()
    for d in range(n_total):
        staying = {int(old_slot[j]) for j in range(m)
                   if old_o[j] == d and new_o[j] == d}
        free = iter(sorted(set(range(cap)) - staying))
        for j in range(m):
            if new_o[j] == d and old_o[j] != d:
                new_slot[j] = next(free)
    moves = [Move(j, int(old_o[j]), int(new_o[j]), 1.0)
             for j in range(m) if old_o[j] != new_o[j]]
    # one in + one out per device per phase => one ppermute per phase
    phases = schedule_phases(moves, phase_budget=1.0)
    static = []
    for ph in phases:
        perm = [(mv.src, mv.dst) for mv in ph]
        send_slot = np.zeros(n_devices, np.int64)
        recv_slot = np.zeros(n_devices, np.int64)
        is_dst = np.zeros(n_devices, bool)
        for mv in ph:
            if mv.src < n_devices:
                send_slot[mv.src] = old_slot[mv.bucket]
            if mv.dst < n_devices:
                recv_slot[mv.dst] = new_slot[mv.bucket]
                is_dst[mv.dst] = True
        static.append((tuple(perm), jnp.asarray(send_slot),
                       jnp.asarray(recv_slot), jnp.asarray(is_dst)))

    def local_fn(state):                       # [1, cap, chunk] per device
        idx = lax.axis_index(axis)
        for perm, send_slot, recv_slot, is_dst in static:
            payload = lax.dynamic_index_in_dim(
                state[0], send_slot[idx], axis=0, keepdims=False)
            recv = lax.ppermute(payload, axis, perm)
            updated = lax.dynamic_update_index_in_dim(
                state[0], recv, recv_slot[idx], axis=0)
            state = jnp.where(is_dst[idx], updated, state[0])[None]
        return state

    slot_map = {j: (int(new_o[j]), int(new_slot[j])) for j in range(m)}
    return local_fn, len(phases), slot_map


def plan_to_permutation(plan: MigrationPlan) -> np.ndarray:
    """Bucket order such that new node i's buckets are contiguous slices —
    the uniform-bucket dry-run layout (bucket j of the new assignment reads
    old bucket perm[j])."""
    n_total = max(plan.old.n_nodes, plan.new.n_nodes)
    new = plan.new.padded(n_total)
    order = []
    for i, (lo, hi) in enumerate(new.intervals):
        order.extend(range(lo, hi))
    return np.asarray(order, dtype=np.int32)


def verify_resharding(plan: MigrationPlan, state,
                      pre_buckets: Sequence) -> None:
    """Assert an executed plan actually moved the real state: walk buckets
    in ``plan_to_permutation`` order (the new contiguous-per-node layout),
    check every bucket's rows now live on its new owner, and that its
    contents are bit-identical to the pre-migration snapshot.

    ``state`` is a ``DeviceBucketedState``; ``pre_buckets`` is the
    pre-migration host view (``state.to_host().buckets``).  Raises
    AssertionError with the offending bucket on any mismatch.
    """
    n_total = max(plan.old.n_nodes, plan.new.n_nodes)
    owner_new = plan.new.padded(n_total).owner_of()
    for j in plan_to_permutation(plan):
        reqs = state.bucket_requests(int(j))
        nodes = set(int(n) for n in state.req_node[reqs])
        if len(reqs) and nodes != {int(owner_new[j])}:
            raise AssertionError(
                f"bucket {j}: rows on nodes {sorted(nodes)}, "
                f"plan owner {int(owner_new[j])}")
        import jax as _jax
        post = state.gather(reqs)
        pre_l = _jax.tree_util.tree_leaves(pre_buckets[int(j)])
        post_l = _jax.tree_util.tree_leaves(post)
        if len(pre_l) != len(post_l):
            raise AssertionError(f"bucket {j}: leaf structure changed")
        for a, b in zip(pre_l, post_l):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                raise AssertionError(
                    f"bucket {j}: contents changed across migration")
