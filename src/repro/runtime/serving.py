"""Elastic stream serving: the live-migration system design (paper §5) as a
deterministic fluid simulation + the word-count quickstart app.

The simulator reproduces the paper's Fig. 8/11 methodology: items arrive per
interval per bucket, nodes drain their buckets' queues at fixed capacity,
and migrations make "to move in" buckets unavailable at the destination
until their phase lands.  Five migration designs are modeled:

* kill_restart — Storm default (paper §5 intro): the whole app stops for the
                 full state transfer + restart overhead.
* live         — §5.2: to-stay buckets never stop; move-in buckets queue
                 until their phase completes; tuples routed with a stale
                 table are forwarded (+1 hop latency).
* progressive  — §5.2 last ¶: mini-migrations bound simultaneously-suspended
                 buckets, trading total duration for smaller latency spikes.
* fluid        — Megaphone-style (Hoffmann et al. 1812.01371) per-bucket
                 sequencing: each bucket pauses only for its own transfer
                 window; ``fluid_batch`` interpolates back toward
                 progressive/live.
* batched_fluid — Megaphone's batched variant: conflict-free parallel
                 rounds (maximum Hopcroft–Karp matchings — each node sends
                 and receives at most one ``fluid_batch``-bucket batch per
                 round), keeping fluid's per-bucket pause while amortizing
                 the per-round coordination barrier (``phase_sync_s``) so
                 total migration time shrinks when many buckets move.

This scalar per-node loop is kept as the small-instance differential-test
oracle; the production array engine is repro.runtime.simulator
(VectorizedServingSim — same semantics, numpy/jax vector ops over all m
buckets, 10k+ buckets in seconds).  The same ElasticOperator machinery
drives the real word-count application in examples/quickstart.py (numpy
counters as operator state).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (
    Assignment, ElasticPlanner, MigrationPlan, satisfies_balance,
)
from .migration import (
    MigrationExecutor, Move, bucket_windows, move_list, naive_duration,
    round_windows, strategy_schedule,
)

SERVING_MODES = ("kill_restart", "live", "progressive", "fluid",
                 "batched_fluid")


def active_nodes(assign: Assignment) -> int:
    """Number of nodes holding at least one bucket."""
    return sum(1 for lo, hi in assign.intervals if hi > lo)


def imbalance_ratio(assign: Assignment, w_t: np.ndarray) -> float:
    """Load imbalance λ = max node load / (W / n_active) − 1.

    The balance constraint (Def. 2.1) is λ ≤ τ; this is the raw signal the
    control plane smooths and thresholds (control.Monitor)."""
    w_t = np.asarray(w_t, dtype=np.float64)
    loads = [w_t[lo:hi].sum() for lo, hi in assign.intervals if hi > lo]
    if not loads:
        return 0.0
    total = float(w_t.sum())
    if total <= 0:
        return 0.0
    return float(max(loads) / (total / len(loads)) - 1.0)


def node_capacity(sim: SimConfig, tau: float, rate: float,
                  n_active: int) -> float:
    """Per-node drain capacity (tuples/s) the simulators provision: headroom
    · (1+τ) · total rate / n_active — a τ-balanced assignment never
    saturates a node in steady state (Def. 2.1)."""
    return sim.headroom * (1 + tau) * max(rate, 1e-9) / max(n_active, 1)


@dataclass
class SimConfig:
    interval_s: float = 60.0         # paper: 1 interval = 1 hour; scaled
    slots_per_interval: int = 60
    headroom: float = 1.15           # capacity = headroom · (1+τ)·rate/n
    bw_bytes_per_s: float = 200e6
    restart_overhead_s: float = 20.0  # JVM/process restart (paper §5.1)
    forward_hop_s: float = 0.002
    service_s: float = 0.001
    phase_sync_s: float = 0.0        # per-phase/round routing-table update
    #                                  barrier (Megaphone reconfiguration);
    #                                  extends the migration clock, pauses
    #                                  no bucket


@dataclass
class IntervalMetrics:
    t: int
    n_nodes: int
    migration_cost_bytes: float = 0.0
    migration_duration_s: float = 0.0
    mean_response_s: float = 0.0
    max_response_s: float = 0.0
    forwarded: int = 0
    dropped_capacity: float = 0.0
    delivered: float = 0.0           # tuples drained this interval
    restored_bytes: float = 0.0      # checkpoint bytes re-read after a
    #                                  node loss (ft.recovery_plan interval)
    imbalance: float = 0.0           # post-plan load imbalance λ (Def. 2.1)


def strategy_windows(moves: List[Move], s_t: np.ndarray, sim: SimConfig,
                     mode: str, max_inflight: int, fluid_batch: int,
                     m: int) -> Tuple[np.ndarray, np.ndarray, float, float]:
    """Compile ``moves`` into the pause schedule a strategy would execute.

    This is the single point where a strategy name becomes concrete
    per-bucket unavailability windows, shared by both serving simulators
    (via ``plan_interval_windows``) and by the control plane's migration
    cost model (``control.MigrationPolicy._score_plan``) — so the policy
    prices exactly the schedule the simulator will execute.

    Strategy → schedule (see runtime/README.md for the catalog):

    * ``kill_restart``  — one bulk transfer; the whole app freezes for the
      transfer plus ``sim.restart_overhead_s``.
    * ``live``          — Rödiger phases, per-node byte budget
      total/#endpoints; window ``[0, phase end)``.
    * ``progressive``   — phases with budget ``max_inflight · max(s_t)``;
      window ``[0, phase end)``.
    * ``fluid``         — phases with budget ``fluid_batch · max(s_t)``;
      window = own phase's ``[start, end)``.
    * ``batched_fluid`` — Hopcroft–Karp matching rounds
      (``migration.schedule_rounds``), ``fluid_batch`` buckets per link
      per round; window = the bucket's **own transfer** within its round
      (``migration.round_windows``).

    Phase-structured strategies charge ``sim.phase_sync_s`` per phase/round
    to the migration clock (routing-table update barrier); it pauses no
    bucket, so it shows up in ``duration_s`` but not in the windows.

    Returns ``(un_from[m], un_until[m], duration_s, freeze_s)``: a bucket
    is unavailable during ``[un_from, un_until)`` seconds into the
    interval; ``freeze_s`` > 0 means the whole app is frozen until then
    (kill_restart only)."""
    un_from = np.zeros(m)
    un_until = np.zeros(m)
    if not moves:
        return un_from, un_until, 0.0, 0.0
    if mode == "kill_restart":
        freeze = naive_duration(moves, sim.bw_bytes_per_s) + \
            sim.restart_overhead_s
        return un_from, un_until, freeze, freeze
    schedule = strategy_schedule(moves, s_t, mode,
                                 max_inflight=max_inflight,
                                 fluid_batch=fluid_batch)
    if mode == "batched_fluid":
        un_from, un_until, clock = round_windows(
            schedule, sim.bw_bytes_per_s, m, sync_s=sim.phase_sync_s)
        return un_from, un_until, clock, 0.0
    un_from, un_until, clock = bucket_windows(
        schedule, sim.bw_bytes_per_s, m, fluid=mode == "fluid",
        sync_s=sim.phase_sync_s)
    return un_from, un_until, clock, 0.0


def plan_interval_windows(planner: ElasticPlanner, assign: Assignment,
                          n_t: int, w_t: np.ndarray, s_t: np.ndarray,
                          sim: SimConfig, mode: str, tau: float,
                          max_inflight: int, fluid_batch: int,
                          met: IntervalMetrics,
                          replan: Optional[bool] = None,
                          verify: Optional[str] = None):
    """One interval's migration decision: trigger, plan, and per-bucket
    unavailability windows.  Shared by the scalar oracle (ElasticServingSim)
    and the vectorized engine (simulator.VectorizedServingSim) so the two
    cannot drift.

    ``replan`` is the control-plane override: ``None`` keeps the legacy
    autonomous trigger (migrate on scale events AND on load-skew violations
    — the paper's rebalancing trigger, §1/§2.1); ``True`` forces a re-plan
    (a MigrationPolicy decided the gain beats the cost); ``False`` holds the
    current assignment even through a violation (the policy decided *not*
    to migrate — callers must then pass n_t == current node count).

    ``verify`` (None | "warn" | "strict") runs the full
    ``analysis.plancheck`` rule catalog — PLN001..PLN006, including the
    τ-feasibility and window rules only this call site has the inputs
    for — on every plan before its windows are charged; "strict" raises
    ``PlanVerificationError``, "warn" prints to stderr.

    Returns (assign', unavailable_from[m], unavailable_until[m], freeze)."""
    m = assign.m
    unavailable_from = np.zeros(m)
    unavailable_until = np.zeros(m)
    freeze = 0.0
    n_cur = active_nodes(assign)
    trigger = n_t != n_cur or not satisfies_balance(assign, w_t, n_t, tau)
    if replan is not None:
        trigger = replan
    if trigger:
        plan = planner.plan(assign, n_t, w_t, s_t, tau=tau)
        moves = move_list(plan, s_t)
        met.migration_cost_bytes = plan.cost
        # no moves: the re-plan changed nothing (e.g. the planner
        # legitimately left a target node empty) — no transfer, no restart
        unavailable_from, unavailable_until, clock, freeze = \
            strategy_windows(moves, s_t, sim, mode, max_inflight,
                             fluid_batch, m)
        met.migration_duration_s = clock
        if verify:
            # lazy: analysis imports this module at load time
            from repro.analysis import plancheck
            findings = plancheck.check_plan(
                plan, s_t, w=w_t, tau=tau, n_target=n_t,
                relax_tau_max=getattr(planner, "relax_tau_max", None),
                expected_old=assign)
            findings += plancheck.check_moves(plan, s_t, moves)
            findings += plancheck.check_schedule(
                moves, strategy_schedule(moves, s_t, mode,
                                         max_inflight=max_inflight,
                                         fluid_batch=fluid_batch), mode)
            findings += plancheck.check_windows(
                moves, unavailable_from, unavailable_until, clock, freeze,
                mode, sim.bw_bytes_per_s, m)
            findings += plancheck.check_permutation(plan)
            plancheck.handle(findings, verify,
                             where=f"plan_interval_windows[t={met.t}, "
                                   f"{mode}]")
        if moves and freeze == 0.0:
            win = np.minimum(unavailable_until, sim.interval_s) - \
                np.minimum(unavailable_from, sim.interval_s)
            met.forwarded = int((w_t / sim.interval_s * win).sum())
        assign = plan.new
    met.imbalance = imbalance_ratio(assign, w_t)
    return assign, unavailable_from, unavailable_until, freeze


def recover_interval(assign: Assignment, failed: set, n_t: int,
                     w_t: np.ndarray, s_t: np.ndarray, tau: float,
                     met: IntervalMetrics) -> Assignment:
    """Node-loss recovery (ft.py), shared by both serving simulators:
    survivors' state stays put where SSM can arrange it, lost buckets
    restore from checkpoint wherever they land.  ``met.restored_bytes``
    reports the strategy-independent checkpoint read;
    ``met.migration_cost_bytes`` accumulates only the survivor network
    moves.  Restore latency is not modeled in the drain — the restored
    bytes are the paper-faithful cost signal."""
    from .ft import recovery_plan, restored_bytes
    met.restored_bytes = restored_bytes(assign, failed, s_t)
    rec = recovery_plan(assign, failed, n_t, w_t, s_t, tau)
    met.migration_cost_bytes += rec.cost
    return rec.new


class ElasticServingSim:
    """Fluid simulation of one operator under an elastic node trace."""

    def __init__(self, m: int, sim: SimConfig, planner: ElasticPlanner,
                 mode: str = "live", max_inflight: int = 4,
                 tau: float = 0.4, fluid_batch: int = 1,
                 verify: Optional[str] = None):
        if mode not in SERVING_MODES:
            raise ValueError(f"mode must be one of {SERVING_MODES}, "
                             f"got {mode!r}")
        self.m = m
        self.sim = sim
        self.planner = planner
        self.mode = mode
        self.max_inflight = max_inflight
        self.tau = tau
        self.fluid_batch = fluid_batch
        self.verify = verify          # None | "warn" | "strict" (plancheck)
        self.assign: Optional[Assignment] = None
        self.queues = np.zeros(m)                  # per-bucket backlog items
        self.t = 0

    # -- stepped observe/act API (control.ControlLoop drives this) ----------
    def reset(self, n0: int) -> "ElasticServingSim":
        """Re-initialize to n0 evenly-cut nodes with empty queues."""
        cuts = np.linspace(0, self.m, int(n0) + 1).round().astype(int)
        self.assign = Assignment.from_boundaries(self.m, list(cuts))
        self.queues = np.zeros(self.m)
        self.t = 0
        return self

    @property
    def bucket_backlog(self) -> np.ndarray:
        """Per-bucket queued tuples right now (monitor input)."""
        return self.queues

    def step_interval(self, w_t: np.ndarray, s_t: np.ndarray,
                      n_t: Optional[int] = None,
                      failed: Optional[set] = None,
                      replan: Optional[bool] = None,
                      mode: Optional[str] = None,
                      fluid_batch: Optional[int] = None,
                      tau: Optional[float] = None) -> IntervalMetrics:
        """Advance one interval: recover lost nodes, decide/plan/execute the
        migration, drain.  All keyword overrides default to the autonomous
        constructor-configured behavior; a ControlLoop passes explicit
        decisions instead.  Call reset() first."""
        if self.assign is None:
            raise RuntimeError("call reset(n0) before step_interval()")
        n_t = active_nodes(self.assign) if n_t is None else int(n_t)
        met = IntervalMetrics(t=self.t, n_nodes=n_t)
        if failed:
            self.assign = recover_interval(self.assign, set(failed), n_t,
                                           w_t, s_t, self.tau, met)
        self.assign, unavailable_from, unavailable_until, freeze_until = \
            plan_interval_windows(
                self.planner, self.assign, n_t, w_t, s_t, self.sim,
                mode if mode is not None else self.mode,
                tau if tau is not None else self.tau,
                self.max_inflight,
                fluid_batch if fluid_batch is not None else self.fluid_batch,
                met, replan=replan, verify=self.verify)
        self._drain(self.t, w_t, self.assign, self.queues,
                    unavailable_from, unavailable_until, freeze_until, met)
        self.t += 1
        return met

    def run(self, w: np.ndarray, s: np.ndarray, node_trace: Sequence[int]
            ) -> List[IntervalMetrics]:
        T, m = w.shape
        assert m == self.m
        self.reset(int(node_trace[0]))
        return [self.step_interval(w[t], s[t], int(node_trace[t]))
                for t in range(T)]

    def _drain(self, t, w_t, assign, queues, unavailable_from,
               unavailable_until, freeze_until,
               met: IntervalMetrics) -> IntervalMetrics:
        sim = self.sim
        K = sim.slots_per_interval
        dt = sim.interval_s / K
        owner = assign.padded(max(assign.n_nodes, 1)).owner_of()
        n_active = max(sum(1 for lo, hi in assign.intervals if hi > lo), 1)
        # per-node capacity provisioned to the balance cap (Def. 2.1):
        # headroom · (1+τ) · rate / n — a τ-balanced assignment never
        # saturates a node in steady state.
        total_rate = max(w_t.sum() / sim.interval_s, 1e-9)
        cap_node = sim.headroom * (1 + self.tau) * total_rate / n_active
        arr_rate = w_t / sim.interval_s
        lat_num = 0.0
        lat_den = 0.0
        max_lat = 0.0
        for k in range(K):
            now = k * dt
            avail = ((now < unavailable_from) | (now >= unavailable_until)) \
                & (now >= freeze_until)
            queues += arr_rate * dt
            # each node drains its available buckets proportionally
            for i in range(len(assign.intervals)):
                lo, hi = assign.intervals[i]
                if hi <= lo:
                    continue
                idx = np.arange(lo, hi)
                a = idx[avail[lo:hi]]
                if len(a) == 0:
                    continue
                budget = cap_node * dt
                q = queues[a]
                drained = np.minimum(q, budget * q / max(q.sum(), 1e-12))
                queues[a] = q - drained
                served = drained.sum()
                # waiting time ≈ queue/service rate at this instant
                if served > 0:
                    wait = q.sum() / cap_node
                    lat_num += served * (wait + sim.service_s)
                    lat_den += served
                    max_lat = max(max_lat, wait + sim.service_s)
                    met.delivered += served
        met.mean_response_s = lat_num / max(lat_den, 1e-12)
        met.max_response_s = max_lat
        met.dropped_capacity = float(queues.sum())
        return met


# ---------------------------------------------------------------------------
# Word-count quickstart operator (real state, numpy counters)
# ---------------------------------------------------------------------------

class ElasticWordCount:
    """The paper's running example with real bucketed counters."""

    def __init__(self, m: int = 64, vocab: int = 10_000,
                 planner: Optional[ElasticPlanner] = None,
                 executor: Optional[MigrationExecutor] = None,
                 n_nodes: int = 2, strategy: Optional[str] = None):
        from .state import BucketedState, route
        self.m, self.vocab = m, vocab
        self.route = lambda words: route(words, m)
        self.state = BucketedState(
            [{"counts": np.zeros(0, np.int64),
              "keys": np.zeros(0, np.int64)} for _ in range(m)])
        cuts = np.linspace(0, m, n_nodes + 1).round().astype(int)
        self.assign = Assignment.from_boundaries(m, list(cuts))
        self.placement = self.assign.owner_of()
        if planner is None:
            from repro.core import TauSchedule
            # tighter τ when growing so added nodes actually take load (§2.1)
            planner = ElasticPlanner(policy="ssm",
                                     tau=TauSchedule(base=1.2, grow=0.2))
        self.planner = planner
        if executor is not None and strategy is not None:
            raise ValueError("pass either executor or strategy, not both "
                             "(set mode on the executor instead)")
        self.executor = executor or MigrationExecutor(mode=strategy or "live")
        self.work = np.zeros(m)

    def ingest(self, words: np.ndarray) -> None:
        buckets = self.route(words)
        for j in np.unique(buckets):
            ws = words[buckets == j]
            b = self.state.buckets[j]
            keys = np.concatenate([b["keys"], ws])
            uniq, counts = np.unique(keys, return_counts=True)
            # merge counts properly: counts of existing keys + new
            prev = dict(zip(b["keys"].tolist(), b["counts"].tolist()))
            new_counts = np.array(
                [prev.get(int(k), 0) for k in uniq], np.int64)
            add = np.zeros_like(new_counts)
            u2, c2 = np.unique(ws, return_counts=True)
            pos = np.searchsorted(uniq, u2)
            add[pos] = c2
            self.state.buckets[j] = {"counts": new_counts + add,
                                     "keys": uniq}
            self.work[j] += len(ws)

    def totals(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for b in self.state.buckets:
            for k, c in zip(b["keys"], b["counts"]):
                out[int(k)] = out.get(int(k), 0) + int(c)
        return out

    def scale(self, n_new: int, tau: Optional[float] = None):
        s = self.state.bucket_bytes()
        w = self.work + 1e-9
        plan = self.planner.plan(self.assign, n_new, w, s, tau)
        report = self.executor.execute(plan, self.state, self.placement)
        self.assign = plan.new
        self.work *= 0.5                       # decay the load estimate
        return plan, report
