"""Elastic stream serving: the live-migration system design (paper §5) as a
deterministic fluid simulation + the word-count quickstart app.

The simulator reproduces the paper's Fig. 8/11 methodology: items arrive per
interval per bucket, nodes drain their buckets' queues at fixed capacity,
and migrations make "to move in" buckets unavailable at the destination
until their phase lands.  Four migration designs are modeled:

* kill_restart — Storm default (paper §5 intro): the whole app stops for the
                 full state transfer + restart overhead.
* live         — §5.2: to-stay buckets never stop; move-in buckets queue
                 until their phase completes; tuples routed with a stale
                 table are forwarded (+1 hop latency).
* progressive  — §5.2 last ¶: mini-migrations bound simultaneously-suspended
                 buckets, trading total duration for smaller latency spikes.
* fluid        — Megaphone-style (Hoffmann et al. 1812.01371) per-bucket
                 sequencing: each bucket pauses only for its own transfer
                 window; ``fluid_batch`` interpolates back toward
                 progressive/live.

This scalar per-node loop is kept as the small-instance differential-test
oracle; the production array engine is repro.runtime.simulator
(VectorizedServingSim — same semantics, numpy/jax vector ops over all m
buckets, 10k+ buckets in seconds).  The same ElasticOperator machinery
drives the real word-count application in examples/quickstart.py (numpy
counters as operator state).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (
    Assignment, ElasticPlanner, MigrationPlan, satisfies_balance,
)
from .migration import (
    MigrationExecutor, Move, bucket_windows, fluid_budget, move_list,
    naive_duration, phase_duration, schedule_phases,
)

SERVING_MODES = ("kill_restart", "live", "progressive", "fluid")


@dataclass
class SimConfig:
    interval_s: float = 60.0         # paper: 1 interval = 1 hour; scaled
    slots_per_interval: int = 60
    headroom: float = 1.15           # capacity = headroom · (1+τ)·rate/n
    bw_bytes_per_s: float = 200e6
    restart_overhead_s: float = 20.0  # JVM/process restart (paper §5.1)
    forward_hop_s: float = 0.002
    service_s: float = 0.001


@dataclass
class IntervalMetrics:
    t: int
    n_nodes: int
    migration_cost_bytes: float = 0.0
    migration_duration_s: float = 0.0
    mean_response_s: float = 0.0
    max_response_s: float = 0.0
    forwarded: int = 0
    dropped_capacity: float = 0.0
    delivered: float = 0.0           # tuples drained this interval
    restored_bytes: float = 0.0      # checkpoint bytes re-read after a
    #                                  node loss (ft.recovery_plan interval)


def plan_interval_windows(planner: ElasticPlanner, assign: Assignment,
                          n_t: int, w_t: np.ndarray, s_t: np.ndarray,
                          sim: SimConfig, mode: str, tau: float,
                          max_inflight: int, fluid_batch: int,
                          met: IntervalMetrics):
    """One interval's migration decision: trigger (scale event or τ
    violation), plan, and per-bucket unavailability windows.  Shared by the
    scalar oracle (ElasticServingSim) and the vectorized engine
    (simulator.VectorizedServingSim) so the two cannot drift.

    Returns (assign', unavailable_from[m], unavailable_until[m], freeze)."""
    m = assign.m
    unavailable_from = np.zeros(m)
    unavailable_until = np.zeros(m)
    freeze = 0.0
    n_cur = sum(1 for lo, hi in assign.intervals if hi > lo)
    # migrate on scale events AND on load-skew violations (the paper's
    # rebalancing trigger, §1/§2.1)
    if n_t != n_cur or not satisfies_balance(assign, w_t, n_t, tau):
        plan = planner.plan(assign, n_t, w_t, s_t, tau=tau)
        moves = move_list(plan, s_t)
        met.migration_cost_bytes = plan.cost
        if not moves:
            # re-plan changed nothing (e.g. the planner legitimately left a
            # target node empty): no transfer, no restart
            pass
        elif mode == "kill_restart":
            freeze = naive_duration(moves, sim.bw_bytes_per_s) + \
                sim.restart_overhead_s
            met.migration_duration_s = freeze
        else:
            budget = None
            if mode == "progressive":
                mx = s_t.max() if len(s_t) else 1.0
                budget = max_inflight * mx
            elif mode == "fluid":
                budget = fluid_budget(s_t, fluid_batch)
            phases = schedule_phases(moves, phase_budget=budget)
            unavailable_from, unavailable_until, clock = bucket_windows(
                phases, sim.bw_bytes_per_s, m, fluid=mode == "fluid")
            met.migration_duration_s = clock
            win = np.minimum(unavailable_until, sim.interval_s) - \
                np.minimum(unavailable_from, sim.interval_s)
            met.forwarded = int((w_t / sim.interval_s * win).sum())
        assign = plan.new
    return assign, unavailable_from, unavailable_until, freeze


class ElasticServingSim:
    """Fluid simulation of one operator under an elastic node trace."""

    def __init__(self, m: int, sim: SimConfig, planner: ElasticPlanner,
                 mode: str = "live", max_inflight: int = 4,
                 tau: float = 0.4, fluid_batch: int = 1):
        if mode not in SERVING_MODES:
            raise ValueError(f"mode must be one of {SERVING_MODES}, "
                             f"got {mode!r}")
        self.m = m
        self.sim = sim
        self.planner = planner
        self.mode = mode
        self.max_inflight = max_inflight
        self.tau = tau
        self.fluid_batch = fluid_batch

    def run(self, w: np.ndarray, s: np.ndarray, node_trace: Sequence[int]
            ) -> List[IntervalMetrics]:
        T, m = w.shape
        assert m == self.m
        cuts = np.linspace(0, m, node_trace[0] + 1).round().astype(int)
        assign = Assignment.from_boundaries(m, list(cuts))
        out: List[IntervalMetrics] = []
        queues = np.zeros(m)                       # per-bucket backlog items
        for t in range(T):
            n_t = int(node_trace[t])
            met = IntervalMetrics(t=t, n_nodes=n_t)
            assign, unavailable_from, unavailable_until, freeze_until = \
                plan_interval_windows(self.planner, assign, n_t, w[t],
                                      s[t], self.sim, self.mode, self.tau,
                                      self.max_inflight, self.fluid_batch,
                                      met)
            out.append(self._drain(t, w[t], assign, queues,
                                   unavailable_from, unavailable_until,
                                   freeze_until, met))
        return out

    def _drain(self, t, w_t, assign, queues, unavailable_from,
               unavailable_until, freeze_until,
               met: IntervalMetrics) -> IntervalMetrics:
        sim = self.sim
        K = sim.slots_per_interval
        dt = sim.interval_s / K
        owner = assign.padded(max(assign.n_nodes, 1)).owner_of()
        n_active = max(sum(1 for lo, hi in assign.intervals if hi > lo), 1)
        # per-node capacity provisioned to the balance cap (Def. 2.1):
        # headroom · (1+τ) · rate / n — a τ-balanced assignment never
        # saturates a node in steady state.
        total_rate = max(w_t.sum() / sim.interval_s, 1e-9)
        cap_node = sim.headroom * (1 + self.tau) * total_rate / n_active
        arr_rate = w_t / sim.interval_s
        lat_num = 0.0
        lat_den = 0.0
        max_lat = 0.0
        for k in range(K):
            now = k * dt
            avail = ((now < unavailable_from) | (now >= unavailable_until)) \
                & (now >= freeze_until)
            queues += arr_rate * dt
            # each node drains its available buckets proportionally
            for i in range(len(assign.intervals)):
                lo, hi = assign.intervals[i]
                if hi <= lo:
                    continue
                idx = np.arange(lo, hi)
                a = idx[avail[lo:hi]]
                if len(a) == 0:
                    continue
                budget = cap_node * dt
                q = queues[a]
                drained = np.minimum(q, budget * q / max(q.sum(), 1e-12))
                queues[a] = q - drained
                served = drained.sum()
                # waiting time ≈ queue/service rate at this instant
                if served > 0:
                    wait = q.sum() / cap_node
                    lat_num += served * (wait + sim.service_s)
                    lat_den += served
                    max_lat = max(max_lat, wait + sim.service_s)
                    met.delivered += served
        met.mean_response_s = lat_num / max(lat_den, 1e-12)
        met.max_response_s = max_lat
        met.dropped_capacity = float(queues.sum())
        return met


# ---------------------------------------------------------------------------
# Word-count quickstart operator (real state, numpy counters)
# ---------------------------------------------------------------------------

class ElasticWordCount:
    """The paper's running example with real bucketed counters."""

    def __init__(self, m: int = 64, vocab: int = 10_000,
                 planner: Optional[ElasticPlanner] = None,
                 executor: Optional[MigrationExecutor] = None,
                 n_nodes: int = 2, strategy: Optional[str] = None):
        from .state import BucketedState, route
        self.m, self.vocab = m, vocab
        self.route = lambda words: route(words, m)
        self.state = BucketedState(
            [{"counts": np.zeros(0, np.int64),
              "keys": np.zeros(0, np.int64)} for _ in range(m)])
        cuts = np.linspace(0, m, n_nodes + 1).round().astype(int)
        self.assign = Assignment.from_boundaries(m, list(cuts))
        self.placement = self.assign.owner_of()
        if planner is None:
            from repro.core import TauSchedule
            # tighter τ when growing so added nodes actually take load (§2.1)
            planner = ElasticPlanner(policy="ssm",
                                     tau=TauSchedule(base=1.2, grow=0.2))
        self.planner = planner
        if executor is not None and strategy is not None:
            raise ValueError("pass either executor or strategy, not both "
                             "(set mode on the executor instead)")
        self.executor = executor or MigrationExecutor(mode=strategy or "live")
        self.work = np.zeros(m)

    def ingest(self, words: np.ndarray) -> None:
        buckets = self.route(words)
        for j in np.unique(buckets):
            ws = words[buckets == j]
            b = self.state.buckets[j]
            keys = np.concatenate([b["keys"], ws])
            uniq, counts = np.unique(keys, return_counts=True)
            # merge counts properly: counts of existing keys + new
            prev = dict(zip(b["keys"].tolist(), b["counts"].tolist()))
            new_counts = np.array(
                [prev.get(int(k), 0) for k in uniq], np.int64)
            add = np.zeros_like(new_counts)
            u2, c2 = np.unique(ws, return_counts=True)
            pos = np.searchsorted(uniq, u2)
            add[pos] = c2
            self.state.buckets[j] = {"counts": new_counts + add,
                                     "keys": uniq}
            self.work[j] += len(ws)

    def totals(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for b in self.state.buckets:
            for k, c in zip(b["keys"], b["counts"]):
                out[int(k)] = out.get(int(k), 0) + int(c)
        return out

    def scale(self, n_new: int, tau: Optional[float] = None):
        s = self.state.bucket_bytes()
        w = self.work + 1e-9
        plan = self.planner.plan(self.assign, n_new, w, s, tau)
        report = self.executor.execute(plan, self.state, self.placement)
        self.assign = plan.new
        self.work *= 0.5                       # decay the load estimate
        return plan, report
