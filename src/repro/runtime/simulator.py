"""Vectorized elastic-serving simulator (paper §5 at production scale).

``repro.runtime.serving.ElasticServingSim`` models one operator with a
scalar per-node Python loop — exact, but it caps Fig. 8/11-style studies at
toy bucket counts.  This module re-states the same fluid-queue semantics as
*array programs*: per-bucket queues, per-bucket unavailability windows and
per-node capacities all live in flat ``[m]`` arrays, so one simulation slot
is a handful of numpy (or jit-compiled jax) ops over all ``m`` buckets at
once.  10k+ buckets over multi-hour traces run in seconds on CPU.

Array layout (one operator):

    queues[m]       f64  per-bucket backlog (tuples)
    owner[m]        i64  bucket -> node id (from Assignment.owner_of())
    arr_rate[m]     f64  per-bucket arrival rate this interval (tuples/s)
    un_from[m]      f64  unavailability window start, seconds into interval
    un_until[m]     f64  unavailability window end
    freeze          f64  scalar: kill-restart full-app freeze deadline

Per slot (dt seconds): buckets outside their unavailability window are
drained by their node proportionally to queue length, bounded by the node
capacity budget ``cap·dt``; waiting time ≈ node queue / capacity.  Node
aggregation is a bincount/segment-sum over ``owner`` — no Python loop over
nodes or buckets.

Migration strategies (see serving.py / README.md): ``kill_restart``,
``live``, ``progressive``, ``fluid`` — Megaphone-style (Hoffmann et al.,
1812.01371) per-bucket sequencing where each bucket pauses only for its
own transfer window, ``fluid_batch`` interpolating kill_restart ↔
progressive ↔ fluid through the same ``schedule_phases`` machinery — and
``batched_fluid``, Megaphone's batched variant: conflict-free parallel
rounds built as maximum Hopcroft–Karp matchings (each node sends/receives
at most one ``fluid_batch``-bucket batch per round) with fluid's
per-bucket pause windows (``migration.schedule_rounds``).

``ChainedDataflowSim`` lifts the engine to chained multi-operator dataflows
(map → aggregate → join): every stage has its own assignment, strategy and
state sizes; a stage's drained tuples are re-routed (hash remap) into the
next stage's buckets one slot later, and migrations overlap freely across
stages.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import Assignment, ElasticPlanner
from .serving import (
    SERVING_MODES, IntervalMetrics, SimConfig, active_nodes,
    plan_interval_windows, recover_interval,
)

MODES = SERVING_MODES


# ---------------------------------------------------------------------------
# One simulation slot as pure array math (shared by the single-operator and
# chained engines).  Mirrors ElasticServingSim._drain bucket-for-bucket.
# ---------------------------------------------------------------------------

def slot_step(queues: np.ndarray, owner: np.ndarray, n_seg: int,
              budget: float, avail: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Drain one slot.  Returns (drained[m], node_q[n_seg], served[n_seg]).

    drained_j = min(q_j, budget · q_j / Σ_node q)  for available buckets —
    each node splits its capacity budget across its available buckets
    proportionally to their backlog (processor sharing).
    """
    qa = np.where(avail, queues, 0.0)
    node_q = np.bincount(owner, weights=qa, minlength=n_seg)
    denom = np.maximum(node_q, 1e-12)
    drained = np.minimum(qa, budget * qa / denom[owner])
    served = np.bincount(owner, weights=drained, minlength=n_seg)
    return drained, node_q, served


def _avail_mask(now: float, un_from: np.ndarray, un_until: np.ndarray,
                freeze: float) -> np.ndarray:
    return ((now < un_from) | (now >= un_until)) & (now >= freeze)


def _node_env(assign: Assignment, w_t: np.ndarray, sim: SimConfig,
              tau: float) -> Tuple[np.ndarray, int, float]:
    """(owner[m], segment count, per-node capacity) for one interval —
    capacity provisioned to the balance cap (Def. 2.1):
    headroom · (1+τ) · rate / n_active."""
    owner = assign.padded(max(assign.n_nodes, 1)).owner_of()
    n_seg = int(owner.max()) + 1
    n_active = max(sum(1 for lo, hi in assign.intervals if hi > lo), 1)
    total_rate = max(w_t.sum() / sim.interval_s, 1e-9)
    cap_node = sim.headroom * (1 + tau) * total_rate / n_active
    return owner, n_seg, cap_node


# ---------------------------------------------------------------------------
# Single-operator vectorized engine
# ---------------------------------------------------------------------------

class VectorizedServingSim:
    """Array-program re-implementation of ElasticServingSim.

    Drop-in: same constructor shape, same ``run(w, s, node_trace) ->
    [IntervalMetrics]`` contract, same planner/trigger logic — differential
    tests pin it to the scalar oracle on small instances.  Extras:

    * ``mode="fluid"`` with a ``fluid_batch`` knob (1 = pure Megaphone).
    * ``backend="jax"`` jit-compiles the K-slot drain loop (recommended for
      m ≳ 10⁵; numpy is already fast at m = 10⁴).
    * ``record_latency=True`` keeps per-slot (latency, served-weight)
      samples for CDF studies (benchmarks/fig12_fluid_vs_progressive.py).
    """

    def __init__(self, m: int, sim: SimConfig, planner: ElasticPlanner,
                 mode: str = "live", max_inflight: int = 4,
                 tau: float = 0.4, fluid_batch: int = 1,
                 backend: str = "numpy", record_latency: bool = False,
                 failures: Optional[Dict[int, set]] = None,
                 verify: Optional[str] = None):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if backend not in ("numpy", "jax"):
            raise ValueError(f"backend must be numpy|jax, got {backend!r}")
        self.m = m
        self.sim = sim
        self.planner = planner
        self.mode = mode
        self.max_inflight = max_inflight
        self.tau = tau
        self.fluid_batch = fluid_batch
        self.verify = verify          # None | "warn" | "strict" (plancheck)
        self.backend = backend
        self.record_latency = record_latency
        # node-loss schedule {interval t: {failed node ids}}; at the start of
        # interval t the failed nodes' buckets are recovered from checkpoint
        # via ft.recovery_plan (node_trace[t] should already reflect the
        # post-failure cluster size, so the regular planner sees no extra
        # scale event)
        self.failures = failures or {}
        self.latency_values: List[np.ndarray] = []
        self.latency_weights: List[np.ndarray] = []
        self.latency_intervals: List[int] = []   # met.t per recorded batch
        self._jit_cache: Dict[tuple, object] = {}
        self.assign: Optional[Assignment] = None
        self.queues = np.zeros(m)
        self.t = 0

    # -- migration planning (the exact scalar-sim logic, shared) -----------
    def _interval_windows(self, assign: Assignment, n_t: int,
                          w_t: np.ndarray, s_t: np.ndarray,
                          met: IntervalMetrics,
                          replan: Optional[bool] = None,
                          mode: Optional[str] = None,
                          fluid_batch: Optional[int] = None,
                          tau: Optional[float] = None
                          ) -> Tuple[Assignment, np.ndarray, np.ndarray,
                                     float]:
        return plan_interval_windows(
            self.planner, assign, n_t, w_t, s_t, self.sim,
            mode if mode is not None else self.mode,
            tau if tau is not None else self.tau,
            self.max_inflight,
            fluid_batch if fluid_batch is not None else self.fluid_batch,
            met, replan=replan, verify=self.verify)

    # -- stepped observe/act API (control.ControlLoop drives this) ----------
    def reset(self, n0: int) -> "VectorizedServingSim":
        """Re-initialize to n0 evenly-cut nodes, empty queues, and fresh
        latency samples."""
        cuts = np.linspace(0, self.m, int(n0) + 1).round().astype(int)
        self.assign = Assignment.from_boundaries(self.m, list(cuts))
        self.queues = np.zeros(self.m)
        self.t = 0
        self.latency_values.clear()
        self.latency_weights.clear()
        self.latency_intervals.clear()
        return self

    @property
    def bucket_backlog(self) -> np.ndarray:
        """Per-bucket queued tuples right now (monitor input)."""
        return self.queues

    def step_interval(self, w_t: np.ndarray, s_t: np.ndarray,
                      n_t: Optional[int] = None,
                      failed: Optional[set] = None,
                      replan: Optional[bool] = None,
                      mode: Optional[str] = None,
                      fluid_batch: Optional[int] = None,
                      tau: Optional[float] = None) -> IntervalMetrics:
        """Advance one interval: recover lost nodes, decide/plan/execute the
        migration, drain.  Overrides default to the autonomous constructor
        configuration; a ControlLoop passes explicit per-decision values
        (replan yes/no, strategy, fluid_batch, plan-τ).  Call reset()
        first."""
        if self.assign is None:
            raise RuntimeError("call reset(n0) before step_interval()")
        n_t = active_nodes(self.assign) if n_t is None else int(n_t)
        met = IntervalMetrics(t=self.t, n_nodes=n_t)
        if failed:
            self.assign = recover_interval(self.assign, set(failed), n_t,
                                           w_t, s_t, self.tau, met)
        self.assign, un_from, un_until, freeze = self._interval_windows(
            self.assign, n_t, w_t, s_t, met, replan=replan, mode=mode,
            fluid_batch=fluid_batch, tau=tau)
        self.queues = self._drain(w_t, self.assign, self.queues, un_from,
                                  un_until, freeze, met)
        self.t += 1
        return met

    def run(self, w: np.ndarray, s: np.ndarray,
            node_trace: Sequence[int]) -> List[IntervalMetrics]:
        T, m = w.shape
        assert m == self.m
        # samples are per-run: interval ids restart at 0 every run
        self.reset(int(node_trace[0]))
        return [self.step_interval(w[t], s[t], int(node_trace[t]),
                                   failed=self.failures.get(t))
                for t in range(T)]

    # -- vectorized drain ---------------------------------------------------
    def _drain(self, w_t: np.ndarray, assign: Assignment,
               queues: np.ndarray, un_from: np.ndarray,
               un_until: np.ndarray, freeze: float,
               met: IntervalMetrics) -> np.ndarray:
        sim = self.sim
        K = sim.slots_per_interval
        dt = sim.interval_s / K
        owner, n_seg, cap_node = _node_env(assign, w_t, sim, self.tau)
        arr_rate = w_t / sim.interval_s
        if self.backend == "jax":
            queues, wait_mat, served_mat = self._drain_jax(
                queues, arr_rate, owner, n_seg, cap_node, dt, K,
                un_from, un_until, freeze)
        else:
            queues, wait_mat, served_mat = self._drain_numpy(
                queues, arr_rate, owner, n_seg, cap_node, dt, K,
                un_from, un_until, freeze)
        # metrics from the [K, n_seg] per-slot per-node matrices
        lat_mat = wait_mat + sim.service_s
        mask = served_mat > 0
        lat_den = float(served_mat[mask].sum())
        met.mean_response_s = float(
            (served_mat * lat_mat)[mask].sum()) / max(lat_den, 1e-12)
        met.max_response_s = float(lat_mat[mask].max()) if mask.any() else 0.0
        met.delivered = float(served_mat.sum())
        met.dropped_capacity = float(queues.sum())
        if self.record_latency and mask.any():
            self.latency_values.append(lat_mat[mask])
            self.latency_weights.append(served_mat[mask])
            self.latency_intervals.append(met.t)
        return queues

    def _drain_numpy(self, queues, arr_rate, owner, n_seg, cap_node, dt, K,
                     un_from, un_until, freeze):
        queues = queues.copy()
        budget = cap_node * dt
        wait_mat = np.zeros((K, n_seg))
        served_mat = np.zeros((K, n_seg))
        for k in range(K):
            now = k * dt
            avail = _avail_mask(now, un_from, un_until, freeze)
            queues += arr_rate * dt
            drained, node_q, served = slot_step(queues, owner, n_seg,
                                                budget, avail)
            queues -= drained
            wait_mat[k] = node_q / cap_node
            served_mat[k] = served
        return queues, wait_mat, served_mat

    def _drain_jax(self, queues, arr_rate, owner, n_seg, cap_node, dt, K,
                   un_from, un_until, freeze):
        import jax.numpy as jnp
        fn = self._get_jit_drain(self.m, n_seg, K)
        q, wait_mat, served_mat = fn(
            jnp.asarray(queues), jnp.asarray(arr_rate),
            jnp.asarray(owner), jnp.asarray(un_from),
            jnp.asarray(un_until), jnp.float32(freeze),
            jnp.float32(cap_node), jnp.float32(dt))
        return (np.asarray(q, np.float64), np.asarray(wait_mat, np.float64),
                np.asarray(served_mat, np.float64))

    def _get_jit_drain(self, m: int, n_seg: int, K: int):
        key = (m, n_seg, K)
        if key in self._jit_cache:
            return self._jit_cache[key]
        import jax
        import jax.numpy as jnp

        def drain(queues, arr_rate, owner, un_from, un_until, freeze,
                  cap_node, dt):
            budget = cap_node * dt

            def body(k, carry):
                queues, wait_mat, served_mat = carry
                now = k.astype(queues.dtype) * dt
                avail = ((now < un_from) | (now >= un_until)) & \
                    (now >= freeze)
                queues = queues + arr_rate * dt
                qa = jnp.where(avail, queues, 0.0)
                node_q = jax.ops.segment_sum(qa, owner,
                                             num_segments=n_seg)
                denom = jnp.maximum(node_q, 1e-12)
                drained = jnp.minimum(qa, budget * qa / denom[owner])
                served = jax.ops.segment_sum(drained, owner,
                                             num_segments=n_seg)
                queues = queues - drained
                wait_mat = wait_mat.at[k].set(node_q / cap_node)
                served_mat = served_mat.at[k].set(served)
                return queues, wait_mat, served_mat

            init = (queues, jnp.zeros((K, n_seg), queues.dtype),
                    jnp.zeros((K, n_seg), queues.dtype))
            return jax.lax.fori_loop(0, K, body, init)

        fn = jax.jit(drain)
        self._jit_cache[key] = fn
        return fn

    def latency_samples(self, intervals: Optional[set] = None
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """(values, weights) pooled over the run (record_latency); pass a
        set of interval ids to restrict (e.g. migration intervals only)."""
        pick = [i for i, t in enumerate(self.latency_intervals)
                if intervals is None or t in intervals]
        if not pick:
            return np.zeros(0), np.zeros(0)
        return (np.concatenate([self.latency_values[i] for i in pick]),
                np.concatenate([self.latency_weights[i] for i in pick]))


def weighted_percentile(values: np.ndarray, weights: np.ndarray,
                        q: float) -> float:
    """q-th percentile (0..100) of a served-weighted latency sample: the
    smallest value whose cumulative weight reaches q% of the total."""
    if len(values) == 0:
        return 0.0
    order = np.argsort(values)
    v, wt = values[order], weights[order]
    cum = np.cumsum(wt)
    total = float(cum[-1])
    if total <= 0:
        return 0.0
    target = q / 100.0 * total
    if target <= 0:
        # q=0: first value carrying any weight (skip a zero-weight head)
        idx = int(np.searchsorted(cum, 0.0, side="right"))
    else:
        idx = int(np.searchsorted(cum, target, side="left"))
    # float round-off (q=100 with a zero-weight tail, or target a hair
    # above cum[-1]) can push searchsorted past the last element — clamp
    return float(v[min(idx, len(v) - 1)])


# ---------------------------------------------------------------------------
# Chained multi-operator dataflows
# ---------------------------------------------------------------------------

@dataclass
class StageSpec:
    """One operator stage in a chained dataflow."""

    name: str
    mode: str = "live"
    tau: float = 0.4
    max_inflight: int = 4
    fluid_batch: int = 1
    planner: Optional[ElasticPlanner] = None
    route_seed: int = 0        # hash remap from the upstream stage's buckets
    state_scale: float = 1.0   # stage state bytes = scale · base s[t]


@dataclass
class StageMetrics:
    metrics: List[IntervalMetrics] = field(default_factory=list)


class ChainedDataflowSim:
    """Chained dataflow (e.g. map → aggregate → join) on the array engine.

    All stages share the bucket count ``m`` and slot clock; stage i's
    drained tuples in slot k arrive at stage i+1 in slot k+1, re-routed by a
    per-stage hash permutation (the downstream operator partitions by a
    different key).  Each stage owns an independent assignment, planner and
    migration strategy, so migrations overlap freely across stages — e.g.
    the aggregate stage can run a fluid migration while the join stage is
    mid-progressive-migration.
    """

    def __init__(self, m: int, sim: SimConfig, stages: Sequence[StageSpec]):
        from .state import route
        self.m = m
        self.sim = sim
        self.stages = list(stages)
        if not self.stages:
            raise ValueError("need at least one stage")
        # bucket remap into stage i (i >= 1): upstream bucket j feeds
        # perm[j]; a permutation-free hash (collisions fine, mass conserved)
        self.remaps = [None] + [
            route(np.arange(m), m, seed=sp.route_seed + 1 + i)
            for i, sp in enumerate(self.stages[1:])]
        self.sims = [VectorizedServingSim(
            m, self.sim,
            sp.planner or ElasticPlanner(policy="greedy"),
            mode=sp.mode, max_inflight=sp.max_inflight, tau=sp.tau,
            fluid_batch=sp.fluid_batch) for sp in self.stages]
        self.assigns: List[Assignment] = []
        self.queues: List[np.ndarray] = []
        self.inflow: List[np.ndarray] = []         # tuples landing next slot
        self.t = 0

    # -- stepped observe/act API --------------------------------------------
    def reset(self, n0) -> "ChainedDataflowSim":
        """Re-initialize every stage to ``n0`` (int, or per-stage sequence)
        evenly-cut nodes with empty queues."""
        S = len(self.stages)
        n0s = [int(n0)] * S if np.ndim(n0) == 0 else [int(x) for x in n0]
        assert len(n0s) == S
        self.assigns = []
        for i in range(S):
            cuts = np.linspace(0, self.m, n0s[i] + 1).round()
            self.assigns.append(
                Assignment.from_boundaries(self.m, list(cuts.astype(int))))
        self.queues = [np.zeros(self.m) for _ in range(S)]
        self.inflow = [np.zeros(self.m) for _ in range(S)]
        self.t = 0
        return self

    @property
    def final_queues(self) -> List[np.ndarray]:
        return self.queues

    @property
    def final_inflow(self) -> List[np.ndarray]:
        return self.inflow

    def step_interval(self, w_t: np.ndarray, s_t: np.ndarray, n_t=None,
                      replan: Optional[bool] = None
                      ) -> List[IntervalMetrics]:
        """Advance the whole chain one interval; returns per-stage metrics.
        ``n_t``: int shared by every stage or a per-stage sequence (None
        keeps each stage's current node count); ``replan`` is forwarded to
        every stage's migration trigger (control-plane override)."""
        if not self.assigns:
            raise RuntimeError("call reset(n0) before step_interval()")
        S = len(self.stages)
        if n_t is None:
            n_ts = [active_nodes(a) for a in self.assigns]
        elif np.ndim(n_t) == 0:
            n_ts = [int(n_t)] * S
        else:
            n_ts = [int(x) for x in n_t]
        K = self.sim.slots_per_interval
        dt = self.sim.interval_s / K
        # per-interval workload estimate seen by each stage: stage 0 sees
        # w_t, downstream stages see the upstream interval totals re-routed
        w_stage = [w_t]
        for i in range(1, S):
            w_stage.append(np.bincount(self.remaps[i],
                                       weights=w_stage[i - 1],
                                       minlength=self.m))
        stage_env = []
        for i in range(S):
            met = IntervalMetrics(t=self.t, n_nodes=n_ts[i])
            s_i = s_t * self.stages[i].state_scale
            self.assigns[i], un_from, un_until, freeze = \
                self.sims[i]._interval_windows(self.assigns[i], n_ts[i],
                                               w_stage[i], s_i, met,
                                               replan=replan)
            owner, n_seg, cap = _node_env(self.assigns[i], w_stage[i],
                                          self.sim, self.stages[i].tau)
            stage_env.append(dict(met=met, un_from=un_from,
                                  un_until=un_until, freeze=freeze,
                                  owner=owner, n_seg=n_seg,
                                  cap=cap, lat_num=0.0, lat_den=0.0,
                                  max_lat=0.0))
        arr0 = w_t / self.sim.interval_s * dt
        queues, inflow = self.queues, self.inflow
        for k in range(K):
            now = k * dt
            # snapshot: stage i's slot-k output lands at stage i+1 in
            # slot k+1 (one-hop pipeline delay)
            adds = [arr0] + [inflow[i] for i in range(1, S)]
            for i in range(S):
                env = stage_env[i]
                queues[i] += adds[i]
                avail = _avail_mask(now, env["un_from"],
                                    env["un_until"], env["freeze"])
                drained, node_q, served = slot_step(
                    queues[i], env["owner"], env["n_seg"],
                    env["cap"] * dt, avail)
                queues[i] -= drained
                if i + 1 < S:
                    inflow[i + 1] = np.bincount(
                        self.remaps[i + 1], weights=drained,
                        minlength=self.m)
                sv = served.sum()
                if sv > 0:
                    wait = node_q / env["cap"]
                    lat = wait + self.sim.service_s
                    act = served > 0
                    env["lat_num"] += float((served * lat)[act].sum())
                    env["lat_den"] += float(served[act].sum())
                    env["max_lat"] = max(env["max_lat"],
                                         float(lat[act].max()))
                    env["met"].delivered += float(sv)
        out = []
        for i in range(S):
            env = stage_env[i]
            met = env["met"]
            met.mean_response_s = env["lat_num"] / max(env["lat_den"], 1e-12)
            met.max_response_s = env["max_lat"]
            met.dropped_capacity = float(queues[i].sum())
            out.append(met)
        self.t += 1
        return out

    def run(self, w: np.ndarray, s: np.ndarray,
            node_traces) -> List[List[IntervalMetrics]]:
        """``w``: external arrivals [T, m]; ``s``: base state sizes [T, m];
        ``node_traces``: [T] shared or list of per-stage [T] traces.
        Returns per-stage IntervalMetrics lists."""
        T, m = w.shape
        assert m == self.m
        S = len(self.stages)
        traces = node_traces if isinstance(node_traces, (list, tuple)) and \
            np.ndim(node_traces[0]) > 0 else [node_traces] * S
        assert len(traces) == S
        self.reset([int(tr[0]) for tr in traces])
        out: List[List[IntervalMetrics]] = [[] for _ in range(S)]
        for t in range(T):
            mets = self.step_interval(w[t], s[t],
                                      [int(tr[t]) for tr in traces])
            for i in range(S):
                out[i].append(mets[i])
        return out

    def end_to_end_latency(self, per_stage: List[List[IntervalMetrics]]
                           ) -> np.ndarray:
        """Per-interval end-to-end mean: stage means + pipeline hop delays."""
        T = len(per_stage[0])
        dt = self.sim.interval_s / self.sim.slots_per_interval
        hops = (len(self.stages) - 1) * dt
        return np.array([
            sum(per_stage[i][t].mean_response_s
                for i in range(len(self.stages))) + hops
            for t in range(T)])
