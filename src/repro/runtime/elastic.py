"""ElasticController: ties the planner, executor, checkpoints and fault
tolerance together — the component a cluster scheduler talks to.

Responsibilities:
* watch per-bucket workload (w_j) and state sizes (|s_j|),
* decide/accept topology changes (scale up/down, rebalance on skew,
  straggler reweighting, failure recovery),
* compute the migration strategy via ElasticPlanner (ssm | mtm | baselines),
* execute it via MigrationExecutor (live / progressive / suspend),
* keep the node-count history that estimates the MTM (paper §2.2),
* periodic checkpoints; restore-with-resharding on restart.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import (
    Assignment, ElasticPlanner, MigrationPlan, MTM, satisfies_balance,
)
from .checkpoint import CheckpointManager
from .control import DecisionRecord
from .ft import SpeedTracker, recovery_plan, restored_bytes
from .migration import MigrationExecutor, MigrationReport
from .state import BucketedState


@dataclass
class ElasticEvent:
    """Legacy view of one topology change.  The controller's source of
    truth is now the ``DecisionRecord`` log shared with the closed-loop
    control plane (``runtime.control``); ``ElasticController.events``
    derives these from it."""

    kind: str                      # scale | rebalance | recover | straggler
    n_before: int
    n_after: int
    cost_bytes: float
    duration_s: float
    details: dict = field(default_factory=dict)


class ElasticController:
    def __init__(self, m: int, n_nodes: int,
                 planner: Optional[ElasticPlanner] = None,
                 executor: Optional[MigrationExecutor] = None,
                 ckpt: Optional[CheckpointManager] = None,
                 tau: float = 1.2, strategy: Optional[str] = None,
                 fluid_batch: int = 1):
        cuts = np.linspace(0, m, n_nodes + 1).round().astype(int)
        self.assign = Assignment.from_boundaries(m, list(cuts))
        self.m = m
        self.tau = tau
        self.planner = planner or ElasticPlanner(policy="ssm")
        if executor is not None and (strategy is not None
                                     or fluid_batch != 1):
            raise ValueError("pass either executor or strategy/fluid_batch, "
                             "not both (set them on the executor instead)")
        self.executor = executor or MigrationExecutor(
            mode=strategy or "live", fluid_batch=fluid_batch)
        self.ckpt = ckpt
        self.history: List[int] = [n_nodes]
        self.speeds = SpeedTracker(n_nodes)
        self.decisions: List[DecisionRecord] = []

    @property
    def events(self) -> List[ElasticEvent]:
        """Legacy event log, derived from the shared decision records."""
        return [ElasticEvent(
            kind=d.action, n_before=d.n_before, n_after=d.n_after,
            cost_bytes=d.cost_bytes, duration_s=d.duration_s,
            details=dict(d.signals)) for d in self.decisions]

    # -- observations --------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return sum(1 for lo, hi in self.assign.intervals if hi > lo)

    def balance_violated(self, w: np.ndarray) -> bool:
        return not satisfies_balance(self.assign, w, self.n_nodes, self.tau)

    def estimate_mtm(self, n_min: int, n_max: int) -> MTM:
        return MTM.estimate(self.history, n_min, n_max)

    # -- actions --------------------------------------------------------------
    def _apply(self, plan: MigrationPlan, state: BucketedState,
               kind: str, reason: str = "", **details
               ) -> Tuple[MigrationPlan, MigrationReport]:
        placement = self.assign.owner_of()
        report = self.executor.execute(plan, state, placement)
        n_before = self.n_nodes
        alive_before = {i for i, (lo, hi) in enumerate(self.assign.intervals)
                        if hi > lo}
        self.assign = plan.new
        alive_after = {i for i, (lo, hi) in enumerate(self.assign.intervals)
                       if hi > lo}
        # the EWMA tracker must follow the topology: survivors (nonempty
        # before AND after) keep their estimate, new/vacated slots reset
        self.speeds.resize(len(self.assign.intervals),
                           keep=sorted(alive_before & alive_after))
        self.history.append(self.n_nodes)
        self.decisions.append(DecisionRecord(
            t=len(self.history) - 2, action=kind, n_before=n_before,
            n_after=self.n_nodes, reason=reason,
            strategy=self.executor.mode,
            cost_bytes=plan.cost,
            restored_bytes=float(details.get("checkpoint_bytes", 0.0)),
            duration_s=report.duration_s, signals=details))
        return plan, report

    def scale(self, n_new: int, w: np.ndarray, state: BucketedState,
              tau: Optional[float] = None):
        plan = self.planner.plan(self.assign, n_new, w,
                                 state.bucket_bytes(),
                                 tau=tau if tau is not None else self.tau)
        return self._apply(plan, state, "scale",
                           reason=f"requested n={n_new}")

    def rebalance(self, w: np.ndarray, state: BucketedState,
                  reason: str = "requested"):
        plan = self.planner.plan(self.assign, self.n_nodes, w,
                                 state.bucket_bytes(), tau=self.tau)
        return self._apply(plan, state, "rebalance", reason=reason)

    def maybe_rebalance(self, w: np.ndarray, state: BucketedState):
        if self.balance_violated(w):
            return self.rebalance(w, state,
                                  reason=f"τ={self.tau} balance violated")
        return None

    def recover(self, failed: Set[int], w: np.ndarray, state: BucketedState,
                n_new: Optional[int] = None):
        """Failure recovery: lost buckets restored from checkpoint, surviving
        state kept in place (ft.recovery_plan)."""
        s = state.bucket_bytes()
        n_target = n_new if n_new is not None else self.n_nodes - len(failed)
        plan = recovery_plan(self.assign, failed, n_target, w, s, self.tau)
        ck_bytes = restored_bytes(self.assign, failed, s)
        return self._apply(plan, state, "recover",
                           reason=f"lost nodes {sorted(failed)}",
                           failed=sorted(failed), checkpoint_bytes=ck_bytes)

    def checkpoint(self, step: int, state: BucketedState, extra=None,
                   async_: bool = True):
        if self.ckpt is None:
            raise RuntimeError("no CheckpointManager configured")
        self.ckpt.save(step, state, self.assign, extra=extra, async_=async_)
