"""Closed-loop elasticity control plane: monitor → decide → plan → execute.

The paper solves *how* to migrate (SSM's optimal plan) and *what* the
migration costs at serving time (§5's strategies); this module decides
*whether* and *when* — the migrate-or-not question Volnes et al.
(arXiv 2203.03501) frame as predicted gain vs migration cost, with the
hysteresis/cooldown policies of Shukla & Simmhan's reliable rapid
elasticity (arXiv 1712.00605).

Pieces, each usable alone:

* ``Monitor``          — folds per-interval simulator metrics (backlog,
                         served latency, imbalance λ vs τ) into EWMA-
                         smoothed ``Signals`` plus a violation streak.
* ``MigrationPolicy``  — decides hold / rebalance / scale_up / scale_down
                         from a cost model: predicted steady-state latency
                         gain (fluid-queue drain forecast) vs migration
                         cost (planned pause windows priced in delayed
                         tuple-seconds), with hysteresis (trigger τ above
                         the plan τ), patience, and cooldown.  Also picks
                         the strategy + ``fluid_batch`` per decision so a
                         bucket's pause stays under a budget.
* ``ControlLoop``      — drives any simulator exposing the stepped
                         ``reset()`` / ``step_interval()`` API
                         (ElasticServingSim, VectorizedServingSim) over a
                         ``scenarios.Scenario``; node losses and capacity
                         changes enter as monitor inputs, not out-of-band
                         calls.  Every interval produces a
                         ``DecisionRecord`` — the audit log shared with
                         ``ElasticController``.
* ``AlwaysMigratePolicy`` / ``NeverMigratePolicy`` — the two baselines the
  closed loop must beat (benchmarks/fig13_controller.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import Assignment, ElasticPlanner, MigrationPlan
from repro.core.ssm import Infeasible
from .migration import move_list
from .serving import (
    SimConfig, active_nodes, imbalance_ratio, node_capacity,
    strategy_windows,
)


# ---------------------------------------------------------------------------
# Signals / monitor
# ---------------------------------------------------------------------------

@dataclass
class Signals:
    """One interval's smoothed view of the system (Monitor output)."""

    t: int
    rate: float                  # tuples/s observed this interval
    backlog: float               # queued tuples at interval end
    backlog_ewma: float
    imbalance: float             # post-plan λ this interval (Def. 2.1)
    imbalance_ewma: float
    latency_ewma: float          # served-weighted mean response, smoothed
    max_latency: float
    violation_streak: int        # consecutive intervals with λ_ewma > trigger
    lost_nodes: int              # nodes that died this interval (ft input)
    capacity: int                # node budget offered this interval

    def as_dict(self) -> dict:
        return {
            "rate": self.rate, "backlog": self.backlog,
            "backlog_ewma": self.backlog_ewma,
            "imbalance": self.imbalance,
            "imbalance_ewma": self.imbalance_ewma,
            "latency_ewma": self.latency_ewma,
            "max_latency": self.max_latency,
            "violation_streak": self.violation_streak,
            "lost_nodes": self.lost_nodes, "capacity": self.capacity,
        }


class Monitor:
    """EWMA smoothing over raw per-interval observations.

    ``trigger`` is the imbalance level that counts as a violation; the
    violation *streak* (consecutive intervals above trigger) is what the
    policy's patience gate reads, so one noisy interval never migrates."""

    def __init__(self, alpha: float = 0.5, trigger: float = 0.4):
        self.alpha = alpha
        self.trigger = trigger
        self.reset()

    def reset(self) -> "Monitor":
        self._imb = None
        self._lat = None
        self._back = None
        self._streak = 0
        return self

    def _ewma(self, prev: Optional[float], x: float) -> float:
        return x if prev is None else self.alpha * x + \
            (1 - self.alpha) * prev

    def observe(self, t: int, rate: float, backlog: float, imbalance: float,
                mean_latency: float = 0.0, max_latency: float = 0.0,
                lost_nodes: int = 0, capacity: int = 0) -> Signals:
        self._imb = self._ewma(self._imb, imbalance)
        self._lat = self._ewma(self._lat, mean_latency)
        self._back = self._ewma(self._back, backlog)
        if self._imb > self.trigger:
            self._streak += 1
        else:
            self._streak = 0
        return Signals(
            t=t, rate=rate, backlog=backlog, backlog_ewma=self._back,
            imbalance=imbalance, imbalance_ewma=self._imb,
            latency_ewma=self._lat, max_latency=max_latency,
            violation_streak=self._streak, lost_nodes=lost_nodes,
            capacity=capacity)

    def observe_metrics(self, met, interval_s: float, lost_nodes: int = 0,
                        capacity: int = 0) -> Signals:
        """Fold an ``IntervalMetrics`` (any of the simulators) directly."""
        return self.observe(
            t=met.t, rate=met.delivered / max(interval_s, 1e-12),
            backlog=met.dropped_capacity, imbalance=met.imbalance,
            mean_latency=met.mean_response_s, max_latency=met.max_response_s,
            lost_nodes=lost_nodes, capacity=capacity)


# ---------------------------------------------------------------------------
# Decisions
# ---------------------------------------------------------------------------

@dataclass
class Decision:
    """What the policy wants the executor/simulator to do this interval."""

    action: str                      # hold|rebalance|scale_up|scale_down|
    #                                  recover|auto
    n_target: int
    replan: Optional[bool]           # None = legacy autonomous trigger
    mode: Optional[str] = None       # strategy override for this decision
    fluid_batch: Optional[int] = None
    tau_plan: Optional[float] = None
    predicted_gain_s: float = 0.0    # forecast mean-latency saving (s/tuple)
    predicted_cost_s: float = 0.0    # forecast pause cost, same units
    reason: str = ""


@dataclass
class DecisionRecord:
    """Decision + realized outcome: the audit log row every control path
    (ControlLoop, ElasticController) emits."""

    t: int
    action: str
    n_before: int
    n_after: int
    reason: str = ""
    strategy: Optional[str] = None
    fluid_batch: Optional[int] = None
    predicted_gain_s: float = 0.0
    predicted_cost_s: float = 0.0
    cost_bytes: float = 0.0          # realized network bytes
    restored_bytes: float = 0.0      # realized checkpoint read (node loss)
    duration_s: float = 0.0          # realized migration duration
    signals: dict = field(default_factory=dict)

    @property
    def migrated(self) -> bool:
        return self.cost_bytes > 0 or self.restored_bytes > 0


# ---------------------------------------------------------------------------
# Cost model helpers (pure functions; both sims' semantics)
# ---------------------------------------------------------------------------

def forecast_mean_wait(node_rate: np.ndarray, node_backlog: np.ndarray,
                       cap_node: float, horizon_s: float,
                       service_s: float) -> float:
    """Fluid-queue drain forecast: served-weighted mean waiting time over
    the horizon if nothing changes.

    Per node, queue(t) = max(0, b0 + (rate − cap)·t): overloaded nodes grow
    linearly, underloaded nodes drain to ~0 and stay there.  The
    simulators' wait is queue/cap at serve time, so the mean wait is the
    time-averaged queue over the horizon divided by cap, weighted by each
    node's arrival rate (≈ its served share)."""
    r = np.asarray(node_rate, dtype=np.float64)
    b0 = np.asarray(node_backlog, dtype=np.float64)
    c = max(cap_node, 1e-12)
    H = max(horizon_s, 1e-12)
    drain = c - r
    # time to empty; inf when the node can't keep up
    with np.errstate(divide="ignore"):
        t_empty = np.where(drain > 0, b0 / np.maximum(drain, 1e-12), np.inf)
    t_e = np.minimum(t_empty, H)
    # integral of queue over [0, H]: triangle down to empty + growth part
    integral = np.where(
        t_empty >= H,
        b0 * H + 0.5 * (r - c) * H * H,          # never empties in horizon
        0.5 * b0 * t_e)                           # drains, then ~0
    integral = np.maximum(integral, 0.0)
    avg_q = integral / H
    wait = avg_q / c
    w_tot = r.sum()
    if w_tot <= 0:
        return service_s
    return float((r * wait).sum() / w_tot) + service_s


def node_loads(assign: Assignment, per_bucket: np.ndarray
               ) -> np.ndarray:
    """Sum ``per_bucket`` over each *active* node's interval."""
    return np.array([per_bucket[lo:hi].sum()
                     for lo, hi in assign.intervals if hi > lo])


def pause_cost_tuple_s(w_rate: np.ndarray, un_from: np.ndarray,
                       un_until: np.ndarray, freeze: float,
                       interval_s: float) -> float:
    """Tuple·seconds of waiting a migration schedule adds: arrivals during
    a bucket's pause window (or the app freeze) wait on average half the
    window.  This is exactly what the simulators charge, so the policy and
    the execution agree on the price."""
    f = min(freeze, interval_s)
    cost = float(w_rate.sum()) * f * f / 2.0
    a = np.minimum(un_from, interval_s)
    b = np.minimum(un_until, interval_s)
    win = np.maximum(b - a, 0.0)
    cost += float((w_rate * win * win).sum()) / 2.0
    return cost


def select_strategy(moves, bw_bytes_per_s: float, pause_budget_s: float
                    ) -> Tuple[str, int]:
    """Pick the migration strategy + ``fluid_batch`` for one decision.

    The contract: no bucket may pause longer than ``pause_budget_s``, and
    subject to that the total migration should finish fast ("To Migrate or
    not to Migrate": the cost side of the decision is both the pause and
    how long the system stays mid-migration).

    * If the whole transfer fits in the budget, one live bulk phase is
      cheapest — nothing to schedule.
    * Otherwise compute the largest ``batch`` whose per-phase per-node
      bytes (batch · max bucket) still meet the budget.  When some node
      has more than ``batch`` moves, fluid needs multiple phases — there
      ``batched_fluid`` strictly dominates: its per-bucket pause is the
      bucket's own transfer (≤ max bucket / BW ≤ the fluid phase width)
      and its Hopcroft–Karp rounds keep every movable node busy while
      amortizing the per-round coordination barrier
      (``SimConfig.phase_sync_s``), so total migration time is shorter
      when many buckets move (Megaphone's batched result).
    * When one batch per node covers everything (≈ one phase), plain fluid
      is equivalent and keeps the simpler schedule.

    Returns ``(mode, fluid_batch)``."""
    if not moves:
        return "live", 1
    total = sum(mv.nbytes for mv in moves)
    mx = max(mv.nbytes for mv in moves)
    if total / bw_bytes_per_s <= pause_budget_s:
        return "live", 1
    batch = max(int(pause_budget_s * bw_bytes_per_s // max(mx, 1.0)), 1)
    sends: Dict[int, int] = {}
    recvs: Dict[int, int] = {}
    for mv in moves:
        sends[mv.src] = sends.get(mv.src, 0) + 1
        recvs[mv.dst] = recvs.get(mv.dst, 0) + 1
    busiest = max(max(sends.values()), max(recvs.values()))
    if busiest > batch:
        return "batched_fluid", batch
    return "fluid", batch


# ---------------------------------------------------------------------------
# The policy
# ---------------------------------------------------------------------------

@dataclass
class PolicyConfig:
    """Knobs of the migrate-or-not decision (runtime/README.md)."""

    tau_trigger: float = 0.4      # act when smoothed λ exceeds this
    tau_plan: float = 0.2         # plan to this tighter τ (hysteresis gap)
    patience: int = 1             # sustained violation intervals before act
    cooldown: int = 1             # min intervals between voluntary acts
    urgent_factor: float = 2.0    # λ_ewma ≥ factor·trigger skips both gates
    max_cost_s: float = 0.05      # insurance replans still skipped above this
    horizon_s: float = 600.0      # expected-benefit amortization horizon
    safety: float = 1.25          # required gain/cost ratio
    min_gain_s: float = 1e-4      # ignore sub-0.1 ms mean-latency gains
    pause_budget_s: float = 2.0   # per-bucket pause target (strategy pick)
    consider_scale: bool = True   # also evaluate n±1 candidates


class MigrationPolicy:
    """Gain-vs-cost migrate-or-not decisions with hysteresis + cooldown.

    ``tau_serve`` is the simulator's serving τ (capacity provisioning);
    ``cfg.tau_trigger``/``cfg.tau_plan`` bound the hysteresis band: act
    only when the smoothed imbalance has exceeded ``tau_trigger`` for
    ``patience`` intervals, then re-balance down to ``tau_plan`` so the
    system re-enters the band with slack."""

    def __init__(self, planner: ElasticPlanner, sim: SimConfig,
                 tau_serve: float = 0.4,
                 cfg: Optional[PolicyConfig] = None):
        self.planner = planner
        self.sim = sim
        self.tau_serve = tau_serve
        self.cfg = cfg or PolicyConfig(tau_trigger=tau_serve,
                                       tau_plan=tau_serve / 2.0)
        self.reset()

    @classmethod
    def for_sim(cls, sv, cfg: Optional[PolicyConfig] = None
                ) -> "MigrationPolicy":
        """Build from a serving simulator's planner/SimConfig/τ."""
        return cls(sv.planner, sv.sim, tau_serve=sv.tau, cfg=cfg)

    def reset(self) -> "MigrationPolicy":
        self.last_migration_t = -10**9
        return self

    def note_migration(self, t: int) -> None:
        """An out-of-policy migration happened (e.g. failure recovery) —
        restart the cooldown clock."""
        self.last_migration_t = t

    # -- scoring ------------------------------------------------------------
    def _score_plan(self, plan: MigrationPlan, w_rate: np.ndarray,
                    queues: np.ndarray, s_est: np.ndarray
                    ) -> Tuple[float, float, str, int]:
        """(gain_s, cost_s, mode, fluid_batch) for executing ``plan`` now.

        gain_s: forecast mean-wait drop over the horizon (s/tuple).
        cost_s: planned pause windows priced in delayed tuple·seconds,
        spread over every tuple served in the horizon — same units."""
        cfg, sim = self.cfg, self.sim
        rate = float(w_rate.sum())
        n_new = active_nodes(plan.new)
        cap_new = node_capacity(sim, self.tau_serve, rate, n_new)
        # backlog travels with its bucket: redistribute by the new owner
        after = forecast_mean_wait(
            node_loads(plan.new, w_rate), node_loads(plan.new, queues),
            cap_new, cfg.horizon_s, sim.service_s)
        n_old = active_nodes(plan.old)
        cap_old = node_capacity(sim, self.tau_serve, rate, n_old)
        hold = forecast_mean_wait(
            node_loads(plan.old, w_rate), node_loads(plan.old, queues),
            cap_old, cfg.horizon_s, sim.service_s)
        gain_s = hold - after
        moves = move_list(plan, s_est)
        mode, batch = select_strategy(moves, sim.bw_bytes_per_s,
                                      cfg.pause_budget_s)
        un_from, un_until, _dur, freeze = strategy_windows(
            moves, s_est, sim, mode, max_inflight=4, fluid_batch=batch,
            m=plan.old.m)
        tuple_s = pause_cost_tuple_s(w_rate, un_from, un_until, freeze,
                                     sim.interval_s)
        cost_s = tuple_s / max(rate * cfg.horizon_s, 1e-12)
        return gain_s, cost_s, mode, batch

    # -- the decision --------------------------------------------------------
    def decide(self, sig: Optional[Signals], assign: Assignment,
               w_est: Optional[np.ndarray], s_est: Optional[np.ndarray],
               queues: np.ndarray, n_cap: int, t: int) -> Decision:
        """One control period's decision.

        ``w_est``/``s_est`` are the *observed* per-bucket workload/state
        (typically the previous interval — the policy never peeks at the
        future); ``queues`` is the current per-bucket backlog; ``n_cap``
        the node budget offered by the cluster this interval."""
        cfg = self.cfg
        n_cur = active_nodes(assign)
        # forced scale-down: the cluster retracted nodes we are using
        if n_cap < n_cur:
            dec = self._planned_decision(
                assign, n_cap, w_est, s_est, queues,
                action="scale_down", reason=f"capacity retracted to {n_cap}")
            self.last_migration_t = t
            return dec
        if sig is None or w_est is None:
            # bootstrap: the initial uniform placement has never seen the
            # load; one replan against the first observed interval is the
            # same free fix every legacy run() caller got at t=0
            self.last_migration_t = t
            return Decision("rebalance", n_cur, True, tau_plan=cfg.tau_plan,
                            reason="bootstrap placement")
        urgent = sig.imbalance_ewma >= cfg.urgent_factor * cfg.tau_trigger
        if not urgent:
            if t - self.last_migration_t <= cfg.cooldown:
                return Decision(
                    "hold", n_cur, False,
                    reason=f"cooldown ({t - self.last_migration_t}"
                           f"/{cfg.cooldown})")
            if sig.violation_streak < cfg.patience:
                why = "balanced" if sig.imbalance_ewma <= cfg.tau_trigger \
                    else f"patience ({sig.violation_streak}/{cfg.patience})"
                return Decision("hold", n_cur, False, reason=why)
        # sustained violation: price the candidates
        w_rate = np.asarray(w_est, dtype=np.float64) / self.sim.interval_s
        # candidates: rebalance in place, or grow toward the offered budget.
        # Voluntary shrink is never a latency play here — aggregate capacity
        # is rate-proportional (independent of n), and fewer nodes always
        # *look* easier to balance, so a shrink candidate degenerates the
        # policy into draining the cluster.  Shrink only when forced above.
        cands = [n_cur]
        if cfg.consider_scale and n_cur + 1 <= n_cap:
            cands.append(n_cur + 1)
        best = None
        for n in cands:
            try:
                plan = self.planner.plan(assign, n, w_est, s_est,
                                         tau=cfg.tau_plan)
            except Infeasible:
                continue
            gain_s, cost_s, mode, batch = self._score_plan(
                plan, w_rate, queues, s_est)
            net = gain_s - cfg.safety * cost_s
            if best is None or net > best[0]:
                best = (net, n, gain_s, cost_s, mode, batch)
        if best is None:
            return Decision("hold", n_cur, False,
                            reason="no feasible candidate plan")
        _net, n, gain_s, cost_s, mode, batch = best
        if best[0] > cfg.min_gain_s:
            why = (f"gain {gain_s:.4g}s beats cost {cost_s:.4g}s over "
                   f"{cfg.horizon_s:.0f}s horizon")
        elif cost_s <= cfg.max_cost_s:
            # the queue forecast is myopic: below the overload margin it
            # sees no gain, but a *sustained* τ violation means drift will
            # push us over it — rebalance now as insurance while the move
            # is still cheap (hysteresis: trigger high, re-plan τ low)
            why = (f"sustained τ violation (λ̄={sig.imbalance_ewma:.2f}), "
                   f"cost {cost_s:.4g}s within budget")
        else:
            return Decision("hold", n_cur, False, predicted_gain_s=gain_s,
                            predicted_cost_s=cost_s,
                            reason="gain does not beat cost")
        action = "rebalance" if n == n_cur else (
            "scale_up" if n > n_cur else "scale_down")
        self.last_migration_t = t
        return Decision(action, n, True, mode=mode, fluid_batch=batch,
                        tau_plan=cfg.tau_plan, predicted_gain_s=gain_s,
                        predicted_cost_s=cost_s, reason=why)

    def _planned_decision(self, assign, n_target, w_est, s_est, queues,
                          action: str, reason: str) -> Decision:
        """Forced migration (capacity retraction): still pick the cheapest
        strategy and report the forecast, but never hold."""
        cfg = self.cfg
        mode: Optional[str] = None
        batch: Optional[int] = None
        gain_s = cost_s = 0.0
        if w_est is not None and s_est is not None:
            w_rate = np.asarray(w_est, dtype=np.float64) / self.sim.interval_s
            try:
                plan = self.planner.plan(assign, n_target, w_est, s_est,
                                         tau=cfg.tau_plan)
                gain_s, cost_s, mode, batch = self._score_plan(
                    plan, w_rate, queues, s_est)
            except Infeasible:
                pass
        return Decision(action, n_target, True, mode=mode,
                        fluid_batch=batch, tau_plan=cfg.tau_plan,
                        predicted_gain_s=gain_s, predicted_cost_s=cost_s,
                        reason=reason)


class AlwaysMigratePolicy:
    """Baseline: follow the offered capacity and let the legacy autonomous
    trigger replan on every scale event or τ violation (what the sims did
    before the control plane existed)."""

    def reset(self) -> "AlwaysMigratePolicy":
        return self

    def note_migration(self, t: int) -> None:
        pass

    def decide(self, sig, assign, w_est, s_est, queues, n_cap: int,
               t: int) -> Decision:
        return Decision("auto", int(n_cap), None, reason="follow capacity")


class NeverMigratePolicy:
    """Baseline: never migrate voluntarily (failure recovery still happens —
    dead nodes cannot serve)."""

    def reset(self) -> "NeverMigratePolicy":
        return self

    def note_migration(self, t: int) -> None:
        pass

    def decide(self, sig, assign, w_est, s_est, queues, n_cap: int,
               t: int) -> Decision:
        return Decision("hold", active_nodes(assign), False, reason="never")


# ---------------------------------------------------------------------------
# The loop
# ---------------------------------------------------------------------------

@dataclass
class ControlReport:
    """One closed-loop run: per-interval metrics + the decision log."""

    metrics: list
    decisions: List[DecisionRecord]

    @property
    def migrations(self) -> int:
        return sum(1 for d in self.decisions if d.migrated)

    @property
    def bytes_moved(self) -> float:
        return float(sum(d.cost_bytes for d in self.decisions))

    @property
    def restored_bytes(self) -> float:
        return float(sum(d.restored_bytes for d in self.decisions))

    @property
    def migration_intervals(self) -> Set[int]:
        return {d.t for d in self.decisions if d.migrated}


class ControlLoop:
    """monitor → decide → plan → execute over a stepped simulator.

    ``sim`` is any single-operator simulator exposing ``reset(n0)`` /
    ``step_interval(w_t, s_t, n_t, failed=..., replan=..., mode=...,
    fluid_batch=..., tau=...)`` / ``bucket_backlog`` — both
    ElasticServingSim and VectorizedServingSim qualify, which is what the
    scalar-vs-vector differential test drives.  Node losses and capacity
    changes arrive from the scenario and are folded into the monitor's
    signals rather than invoked out-of-band.

    ``verify`` (None | "warn" | "strict") turns on the
    ``analysis.plancheck`` rule catalog on every plan the loop's
    simulator charges: "strict" raises ``PlanVerificationError`` before a
    bad plan's windows reach the drain."""

    def __init__(self, sim, policy=None, monitor: Optional[Monitor] = None,
                 verify: Optional[str] = None):
        self.sim = sim
        if verify is not None:
            sim.verify = verify
        self.policy = policy if policy is not None else \
            MigrationPolicy.for_sim(sim)
        trig = getattr(getattr(self.policy, "cfg", None), "tau_trigger",
                       getattr(sim, "tau", 0.4))
        self.monitor = monitor or Monitor(trigger=trig)

    def run(self, scenario) -> ControlReport:
        sim = self.sim
        sim.reset(scenario.n0)
        self.policy.reset()
        self.monitor.reset()
        sig: Optional[Signals] = None
        w_prev: Optional[np.ndarray] = None
        s_prev: Optional[np.ndarray] = None
        decisions: List[DecisionRecord] = []
        mets = []
        T = len(scenario.w)
        for t in range(T):
            failed = scenario.failures.get(t)
            cap = int(scenario.capacity[t])
            n_before = active_nodes(sim.assign)
            if failed:
                # node loss: recovery is not optional; the decision records
                # it and the monitor sees it as a lost-node signal
                n_target = max(min(n_before - len(failed), cap), 1)
                decision = Decision(
                    "recover", n_target, False,
                    reason=f"lost nodes {sorted(failed)}")
                self.policy.note_migration(t)
            else:
                decision = self.policy.decide(
                    sig, sim.assign, w_prev, s_prev, sim.bucket_backlog,
                    cap, t)
            met = sim.step_interval(
                scenario.w[t], scenario.s[t], n_t=decision.n_target,
                failed=failed, replan=decision.replan, mode=decision.mode,
                fluid_batch=decision.fluid_batch, tau=decision.tau_plan)
            sig = self.monitor.observe_metrics(
                met, self.sim.sim.interval_s,
                lost_nodes=len(failed) if failed else 0, capacity=cap)
            decisions.append(DecisionRecord(
                t=t, action=decision.action, n_before=n_before,
                n_after=active_nodes(sim.assign), reason=decision.reason,
                strategy=decision.mode, fluid_batch=decision.fluid_batch,
                predicted_gain_s=decision.predicted_gain_s,
                predicted_cost_s=decision.predicted_cost_s,
                cost_bytes=met.migration_cost_bytes,
                restored_bytes=met.restored_bytes,
                duration_s=met.migration_duration_s,
                signals=sig.as_dict()))
            mets.append(met)
            w_prev, s_prev = scenario.w[t], scenario.s[t]
        return ControlReport(metrics=mets, decisions=decisions)
