from .state import (
    BucketedState, DeviceBucketedState, cache_batch_axes, owner_lookup,
    route,
)
from .migration import (
    JaxBackend, MigrationExecutor, MigrationReport, Move, SimBackend,
    bucket_windows, fluid_budget, hopcroft_karp,
    make_collective_migration, make_migration_step, move_list,
    naive_duration, phase_duration, plan_to_permutation, required_capacity,
    round_windows, schedule_phases, schedule_rounds, verify_resharding,
)
from .checkpoint import CheckpointManager, RestoreReport
from .ft import (
    SpeedTracker, physical_migration_cost, recovery_plan, restored_bytes,
    weighted_plan,
)
from .control import (
    AlwaysMigratePolicy, ControlLoop, ControlReport, Decision,
    DecisionRecord, MigrationPolicy, Monitor, NeverMigratePolicy,
    PolicyConfig, Signals,
)
from .elastic import ElasticController, ElasticEvent
from .scenarios import SCENARIOS, Scenario
from .serving import (
    SERVING_MODES, ElasticServingSim, ElasticWordCount, IntervalMetrics,
    SimConfig, active_nodes, imbalance_ratio, strategy_windows,
)
from .simulator import (
    ChainedDataflowSim, StageSpec, VectorizedServingSim, slot_step,
    weighted_percentile,
)

__all__ = [
    "BucketedState", "DeviceBucketedState", "cache_batch_axes",
    "owner_lookup", "route",
    "JaxBackend", "MigrationExecutor", "MigrationReport", "Move",
    "SimBackend", "bucket_windows", "fluid_budget", "hopcroft_karp",
    "make_collective_migration", "make_migration_step",
    "move_list", "naive_duration", "phase_duration", "plan_to_permutation",
    "required_capacity", "round_windows", "schedule_phases",
    "schedule_rounds", "verify_resharding",
    "CheckpointManager", "RestoreReport",
    "SpeedTracker", "physical_migration_cost", "recovery_plan",
    "restored_bytes", "weighted_plan",
    "AlwaysMigratePolicy", "ControlLoop", "ControlReport", "Decision",
    "DecisionRecord", "MigrationPolicy", "Monitor", "NeverMigratePolicy",
    "PolicyConfig", "Signals",
    "ElasticController", "ElasticEvent",
    "SCENARIOS", "Scenario",
    "SERVING_MODES", "ElasticServingSim", "ElasticWordCount",
    "IntervalMetrics", "SimConfig", "active_nodes", "imbalance_ratio",
    "strategy_windows",
    "ChainedDataflowSim", "StageSpec", "VectorizedServingSim", "slot_step",
    "weighted_percentile",
]
