from .state import BucketedState, owner_lookup, route
from .migration import (
    JaxBackend, MigrationExecutor, MigrationReport, Move, SimBackend,
    make_collective_migration, make_migration_step, move_list,
    naive_duration, phase_duration, plan_to_permutation, required_capacity,
    schedule_phases,
)
from .checkpoint import CheckpointManager, RestoreReport
from .ft import (
    SpeedTracker, physical_migration_cost, recovery_plan, restored_bytes,
    weighted_plan,
)
from .elastic import ElasticController, ElasticEvent
from .serving import ElasticServingSim, ElasticWordCount, SimConfig

__all__ = [
    "BucketedState", "owner_lookup", "route",
    "JaxBackend", "MigrationExecutor", "MigrationReport", "Move",
    "SimBackend", "make_collective_migration", "make_migration_step",
    "move_list", "naive_duration", "phase_duration", "plan_to_permutation",
    "required_capacity", "schedule_phases",
    "CheckpointManager", "RestoreReport",
    "SpeedTracker", "physical_migration_cost", "recovery_plan",
    "restored_bytes", "weighted_plan",
    "ElasticController", "ElasticEvent",
    "ElasticServingSim", "ElasticWordCount", "SimConfig",
]
