"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, local window 2048.
[arXiv:2402.19427; unverified]
Pattern (rglru, rglru, attn) tiled over 38 layers: 12 full blocks + 2-layer
rglru tail.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=4096,
    act="silu",
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    n_layers=8,                      # 2 full pattern blocks + 2-layer tail
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    window=16,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=64,
    act="silu",
)
