"""qwen2.5-32b [dense] — GQA with QKV bias.

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
[hf:Qwen/Qwen2.5-0.5B; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="silu",
)

SMOKE = ModelConfig(
    name="qwen2.5-32b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    qkv_bias=True,
    act="silu",
)
