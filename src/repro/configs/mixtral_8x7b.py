"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention (4096).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2, SWA.
[arXiv:2401.04088; hf]
SWA bounds the decode KV cache to the window ⇒ long_500k decode runs.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    n_experts=8,
    top_k=2,
    window=4096,
    act="silu",
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    n_experts=4,
    top_k=2,
    capacity_factor=4.0,   # no-drop capacity for exact prefill/decode consistency tests
    window=16,
    act="silu",
)
