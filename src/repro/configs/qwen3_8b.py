"""qwen3-8b [dense] — GQA with per-head q/k RMSNorm (qk_norm).

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
[hf:Qwen/Qwen3-8B; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="silu",
)

SMOKE = ModelConfig(
    name="qwen3-8b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    qk_norm=True,
    act="silu",
)
