"""Architecture registry: the ten assigned architectures as selectable
configs (``--arch <id>``), each with a FULL config (dry-run only) and a
SMOKE reduction of the same family (CPU tests).

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every model
input of an (arch × shape) cell — weak-type-correct, shardable, no device
allocation.  Modality frontends are stubs: audio supplies precomputed frame
embeddings, vlm supplies precomputed patch embeddings (per the assignment).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import (
    ModelConfig, ShapeConfig, SHAPES, shape_applicable,
)

from . import (
    falcon_mamba_7b,
    internvl2_2b,
    mixtral_8x7b,
    olmo_1b,
    phi35_moe,
    qwen25_32b,
    qwen25_3b,
    qwen3_8b,
    recurrentgemma_9b,
    whisper_large_v3,
)

_MODULES = {
    "recurrentgemma-9b": recurrentgemma_9b,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "mixtral-8x7b": mixtral_8x7b,
    "qwen2.5-32b": qwen25_32b,
    "qwen3-8b": qwen3_8b,
    "olmo-1b": olmo_1b,
    "qwen2.5-3b": qwen25_3b,
    "whisper-large-v3": whisper_large_v3,
    "falcon-mamba-7b": falcon_mamba_7b,
    "internvl2-2b": internvl2_2b,
}

ARCH_IDS = tuple(_MODULES)

# Beyond-baseline perf variants (EXPERIMENTS.md §Perf).  Semantics-preserving:
# head padding zero-inits the extra slots; bf16_reduce changes only the
# all-reduced activation dtype (f32 MXU accumulation kept).
OPT_OVERRIDES = {
    "qwen2.5-32b": dict(head_pad_multiple=16),   # 40→48 heads: TP instead of
                                                 # 16× replicated attention
    "whisper-large-v3": dict(head_pad_multiple=16),  # 20→32 q+kv heads (MHA)
    "mixtral-8x7b": dict(bf16_reduce=True, fused_gu=True,
                     remat_save_reduced=True),
    "phi3.5-moe-42b-a6.6b": dict(bf16_reduce=True, fused_gu=True),
    "qwen3-8b": dict(bf16_reduce=True, fused_gu=True),
    "internvl2-2b": dict(bf16_reduce=True),
    "recurrentgemma-9b": dict(bf16_reduce=True),
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return _MODULES[arch].FULL


def get_optimized(arch: str) -> ModelConfig:
    return get_config(arch).replace(**OPT_OVERRIDES.get(arch, {}))


def get_smoke(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


def decode_cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """KV-cache length for decode cells (window-bounded for SWA/local)."""
    if cfg.window:
        return min(shape.seq_len, cfg.window)
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                batch_override: int = None,
                aligned_decode: bool = False
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct inputs for one (arch × shape) cell.

    train/prefill: {"tokens" [B,S] (+ frames/patches)}.
    decode: {"tokens" [B,1], "pos" [B]} — the cache is built separately via
    ``cache_specs`` (it is carried state, not a stream input).
    """
    B = batch_override or shape.global_batch
    i32 = jnp.int32
    if shape.kind == "decode":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct(() if aligned_decode else (B,), i32),
        }
        return specs
    S = shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), dt)
    return specs


def smoke_batch(cfg: ModelConfig, batch: int = 2, seq: int = 32,
                seed: int = 0) -> Dict[str, jax.Array]:
    """Concrete random inputs for the SMOKE config (CPU tests)."""
    key = jax.random.PRNGKey(seed)
    out = {"tokens": jax.random.randint(key, (batch, seq), 0,
                                        cfg.vocab_size, jnp.int32)}
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(
            key, (batch, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            key, (batch, cfg.vision_tokens, cfg.d_model), dt)
    return out


__all__ = [
    "ARCH_IDS", "ModelConfig", "ShapeConfig", "SHAPES",
    "decode_cache_len", "get_config", "get_smoke", "input_specs",
    "shape_applicable", "smoke_batch",
]
