"""qwen2.5-3b [dense] — GQA kv=2, QKV bias.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
[hf:Qwen/Qwen2.5-0.5B; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="silu",
)

SMOKE = ModelConfig(
    name="qwen2.5-3b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    act="silu",
)
