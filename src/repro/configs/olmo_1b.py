"""olmo-1b [dense] — non-parametric LayerNorm, MHA (kv == heads).

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.
[arXiv:2402.00838; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50_304,
    norm="ln_np",
    nonparametric_ln=True,
    act="silu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="olmo-1b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    norm="ln_np",
    nonparametric_ln=True,
    act="silu",
    tie_embeddings=True,
)
