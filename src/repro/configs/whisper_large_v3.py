"""whisper-large-v3 [audio] — encoder-decoder; conv frontend STUBBED.

32L (enc) + 32L (dec) d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866.
[arXiv:2212.04356; unverified]

The conv/mel frontend is a stub per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, 1500, d_model].  Decode shapes
exercise the *decoder* (self-attn KV cache + cross-attention to the encoded
frames).  Positional scheme: RoPE (deviation from Whisper's learned/sinusoid
embeddings — backbone dims are what the roofline needs; noted in DESIGN.md).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    act="gelu",
    norm="ln",
    encoder_layers=32,
    encoder_seq=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    act="gelu",
    norm="ln",
    encoder_layers=2,
    encoder_seq=24,
)
