"""internvl2-2b [vlm] — InternLM2 LM backbone; InternViT frontend STUBBED.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
[arXiv:2404.16821; hf]

The InternViT vision tower is a stub per the assignment: ``input_specs()``
provides precomputed patch embeddings [B, 256, d_model] prefixed to the
token sequence.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    vision_tokens=256,
    act="silu",
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    vision_tokens=8,
    act="silu",
)
