"""falcon-mamba-7b [ssm] — attention-free Mamba-1 architecture.

64L d_model=4096 (attn-free) vocab=65024, ssm_state=16, expand=2.
[arXiv:2410.05355; unverified]
Decode state is O(1) in sequence length ⇒ long_500k runs.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65_024,
    ssm_state=16,
    expand=2,
    block_pattern=("mamba",),
    act="silu",
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm_state=8,
    expand=2,
    block_pattern=("mamba",),
    act="silu",
)
