import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not move them.

"""Multi-pod dry run (deliverable e).

For every (architecture × input-shape) cell, lower + compile the real
train/prefill/serve step against the production meshes:

    single-pod : (16, 16)    axes ("data", "model")        = 256 chips
    multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

and record ``memory_analysis()`` (proves it fits), ``cost_analysis()``, and
the loop-aware HLO roofline terms (repro.roofline).  Failures here —
sharding mismatches, OOM at compile, unsupported collectives — are bugs.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCH_IDS, SHAPES, decode_cache_len, get_config, get_optimized,
    input_specs, shape_applicable,
)
from repro.launch.mesh import data_size, make_production_mesh, model_size
from repro.launch.shardings import (
    batch_specs, cache_specs, opt_state_specs, param_specs, to_named,
)
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import init_cache, init_params
from repro.optim import OptConfig, init_opt_state
from repro.roofline.hlo import analyze
from repro.roofline.terms import roofline_terms


def _eval_shape(fn, *args):
    return jax.eval_shape(fn, *args)


def build_cell(arch: str, shape_name: str, mesh, *, schedule: str = "masked",
               variant: str = "base", microbatches: int = 1):
    """Returns (jitted_fn, arg_structs) ready to .lower(*arg_structs)."""
    cfg = get_optimized(arch) if variant == "opt" else get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise SkipCell(why)
    key = jax.random.PRNGKey(0)
    params_s = _eval_shape(functools.partial(init_params, cfg), key)
    pspecs = param_specs(cfg, mesh, params_s)
    pshard = to_named(pspecs, mesh)

    if shape.kind == "train":
        opt_cfg = OptConfig()
        opt_s = _eval_shape(init_opt_state, params_s)
        ospecs = opt_state_specs(pspecs, params_s, mesh)
        oshard = to_named(ospecs, mesh)
        binput = input_specs(cfg, shape)
        bshard = to_named(batch_specs(cfg, mesh, binput), mesh)
        step = make_train_step(cfg, opt_cfg, schedule=schedule,
                               microbatches=microbatches,
                               accum_dtype=jnp.bfloat16
                               if microbatches > 1 else jnp.float32)
        jf = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        return jf, (params_s, opt_s, binput), cfg, shape

    if shape.kind == "prefill":
        cache_len = decode_cache_len(cfg, shape)
        cache_s = _eval_shape(
            functools.partial(init_cache, cfg, shape.global_batch, cache_len))
        cshard = to_named(cache_specs(cfg, mesh, cache_s), mesh)
        binput = input_specs(cfg, shape)
        bshard = to_named(batch_specs(cfg, mesh, binput), mesh)
        step = make_prefill_step(cfg, schedule=schedule)
        jf = jax.jit(step, in_shardings=(pshard, bshard, cshard),
                     out_shardings=(None, cshard), donate_argnums=(2,))
        return jf, (params_s, binput, cache_s), cfg, shape

    # decode
    cache_len = decode_cache_len(cfg, shape)
    seq_shard = shape.name == "long_500k"
    cache_s = _eval_shape(
        functools.partial(init_cache, cfg, shape.global_batch, cache_len))
    cshard = to_named(cache_specs(cfg, mesh, cache_s, seq_shard=seq_shard),
                      mesh)
    binput = input_specs(cfg, shape, aligned_decode=(variant == "opt"))
    bshard = to_named(batch_specs(cfg, mesh, binput), mesh)
    step = make_serve_step(cfg)
    jf = jax.jit(step, in_shardings=(pshard, cshard, bshard["tokens"],
                                     bshard["pos"]),
                 out_shardings=(None, cshard), donate_argnums=(1,))
    return jf, (params_s, cache_s, binput["tokens"], binput["pos"]), cfg, shape


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             schedule: str = "masked", tag: str = "",
             variant: str = "base", microbatches: int = 1) -> dict:
    multi = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = mesh.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "devices": n_dev, "schedule": schedule, "variant": variant,
           "status": "ok"}
    t0 = time.time()
    try:
        jf, args, cfg, shape = build_cell(arch, shape_name, mesh,
                                          schedule=schedule,
                                          variant=variant,
                                          microbatches=microbatches)
        with mesh, jax.sharding.set_mesh(mesh):
            lowered = jf.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            rec[k] = getattr(mem, k, None)
        ca = compiled.cost_analysis() or {}
        rec["xla_flops_per_device"] = float(ca.get("flops", 0.0))
        rec["xla_bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        hlo = compiled.as_text()
        costs = analyze(hlo, total_devices=n_dev)
        rec["dot_flops_per_device"] = costs.dot_flops
        rec["collective_bytes_per_device"] = costs.collective_bytes
        rec["hbm_bytes_per_device"] = costs.hbm_bytes
        rec["collective_breakdown"] = costs.collective_breakdown
        rec["collective_counts"] = costs.collective_counts
        rec["while_trips"] = costs.while_trips[:64]
        rec.update(roofline_terms(cfg, SHAPES[shape_name], costs, n_dev))
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
    except SkipCell as e:
        rec["status"] = "skipped"
        rec["why"] = str(e)
    except Exception as e:  # noqa: BLE001 — record the failure, don't mask it
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    out_dir.mkdir(parents=True, exist_ok=True)
    sfx = f"_{tag}" if tag else ""
    path = out_dir / f"{arch}_{shape_name}_{mesh_kind}{sfx}.json"
    path.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--schedule", default="masked",
                    choices=["masked", "folded"])
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out = Path(args.out)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]
    failures = 0
    for a, s in cells:
        for mk in meshes:
            rec = run_cell(a, s, mk, out, schedule=args.schedule,
                           tag=args.tag, variant=args.variant,
                           microbatches=args.microbatches)
            status = rec["status"]
            extra = ""
            if status == "ok":
                extra = (f" compute={rec['compute_s']:.4g}s"
                         f" mem={rec['memory_s']:.4g}s"
                         f" coll={rec['collective_s']:.4g}s"
                         f" bottleneck={rec['bottleneck']}"
                         f" compile={rec['compile_s']}s")
            elif status == "failed":
                failures += 1
                extra = " " + rec["error"][:200]
            print(f"[{status:7s}] {a} × {s} × {mk}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
