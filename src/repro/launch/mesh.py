"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device;
only dryrun.py sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before first jax init.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests / examples use (1,1) or (1,2) CPU meshes)."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The elastic batch axes: ("pod","data") on multi-pod, ("data",) else."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def data_size(mesh: Mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)
