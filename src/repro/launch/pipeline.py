"""GPipe-style pipeline parallelism (opt-in; DESIGN.md §5).

Formulation: stage-stacked parameters (leading [n_stages] axis) and a
skewed clock.  Each tick vmaps the stage function across all stages on a
rotating activation buffer; the rotation (`jnp.roll` along the stage dim)
is what GSPMD lowers to a `collective-permute` when the stage dimension is
sharded over a mesh axis — so the same function is both the single-host
reference (stage dim unsharded, validated numerically in
tests/test_pipeline.py) and the distributed schedule (stage dim sharded:
each device computes its stage's slice and the roll becomes neighbor
ICI traffic).

Bubble fraction is the usual (S−1)/(T+S−1); utilization improves with more
microbatches exactly as in GPipe.  The transformer hook
(``pipeline_depth_fn``) splits the scanned layer stack into S equal stage
slices.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def pipeline_apply(stage_params: Any, x_micro: jax.Array,
                   stage_fn: Callable[[Any, jax.Array], jax.Array]
                   ) -> jax.Array:
    """Run microbatches through a pipeline of stages.

    stage_params: pytree with leading [S] stage axis on every leaf.
    x_micro:      [n_micro, mb, ...] microbatched input activations.
    stage_fn:     (per-stage params, [mb, ...]) -> [mb, ...].

    Returns [n_micro, mb, ...] outputs (stage S−1's results, in microbatch
    order).  Total ticks = n_micro + S − 1 (the GPipe bubble).
    """
    S = jax.tree_util.tree_leaves(stage_params)[0].shape[0]
    n_micro = x_micro.shape[0]
    T = n_micro + S - 1
    buf = jnp.zeros((S,) + x_micro.shape[1:], x_micro.dtype)

    def tick(buf, t):
        # inject microbatch t into stage 0's slot (zeros after the last one)
        idx = jnp.minimum(t, n_micro - 1)
        inject = lax.dynamic_index_in_dim(x_micro, idx, 0, keepdims=False)
        inject = jnp.where(t < n_micro, inject, jnp.zeros_like(inject))
        buf = buf.at[0].set(inject)
        y = jax.vmap(stage_fn)(stage_params, buf)     # all stages compute
        out = y[S - 1]                                # completed microbatch
        # rotate: stage s+1's next input is stage s's output.  With the
        # stage dim sharded this roll IS the inter-stage collective-permute.
        buf = jnp.roll(y, 1, axis=0)
        return buf, out

    _, outs = lax.scan(tick, buf, jnp.arange(T))
    return outs[S - 1:]                                # drop warmup bubble


def stack_stages(params_layers: Any, n_stages: int) -> Any:
    """Reshape layer-stacked params [L, ...] into [S, L/S, ...]."""
    def one(x):
        L = x.shape[0]
        assert L % n_stages == 0, f"layers {L} must divide stages {n_stages}"
        return x.reshape((n_stages, L // n_stages) + x.shape[1:])

    return jax.tree_util.tree_map(one, params_layers)


def pipeline_depth_fn(cfg, layer_fn: Callable) -> Callable:
    """Stage function applying L/S scanned layers (one stage's slice)."""
    def stage_fn(stage_layer_params, x):
        def body(carry, p):
            return layer_fn(carry, p), None
        y, _ = lax.scan(body, x, stage_layer_params)
        return y

    return stage_fn


def pipeline_transformer_blocks(params_blocks: Tuple, x: jax.Array,
                                cfg, positions, n_stages: int,
                                n_micro: int, schedule: str = "masked"
                                ) -> jax.Array:
    """Pipeline the decoder block stack of a uniform-pattern model.

    Only single-kind patterns pipeline cleanly (dense/MoE/Mamba stacks);
    hybrid patterns keep the non-pipelined scan.  x [B, S, d] is split on
    batch into n_micro microbatches.
    """
    assert len(cfg.block_pattern) == 1, "pipeline needs a uniform pattern"
    from repro.models.transformer import _layer_full

    kind = cfg.block_pattern[0]
    B = x.shape[0]
    assert B % n_micro == 0
    mb = B // n_micro
    x_micro = x.reshape((n_micro, mb) + x.shape[1:])
    pos_micro = positions.reshape((n_micro, mb) + positions.shape[1:])
    staged = stack_stages(params_blocks[0], n_stages)
    # positions are identical for every batch-major microbatch slice, so
    # the stage closure uses the first microbatch's positions
    pos0 = pos_micro[0]

    def stage_fn(stage_params, y):
        def body(carry, p):
            return _layer_full(carry, p, kind, cfg, pos0, schedule), None
        y, _ = lax.scan(body, y, stage_params)
        return y

    out = pipeline_apply(staged, x_micro, stage_fn)
    return out.reshape((B,) + x.shape[1:])
