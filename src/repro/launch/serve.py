"""Serving driver: batched prefill + decode where the REAL jax KV cache is
the bucketed operator state — a live elastic resize physically reshards it.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --requests 16 --prompt-len 24 --gen 16 --resize-at 8:3

Requests are hashed into m buckets (repro.runtime.route); each serving node
owns a contiguous bucket interval and holds its requests' KV/recurrent rows
in its own device buffer (``DeviceBucketedState``: per-node cache shards,
device-to-device when multiple jax devices back the nodes).  Decode runs
per node on its local shard.  ``--resize-at step:n`` triggers a live
elastic event mid-decode: SSM plans the minimal KV movement from the
*actual* per-bucket byte sizes, ``MigrationExecutor`` +
``JaxBackend`` execute the phases as real row transfers between shards
(wall-clock measured), routing follows the new bucket ownership, and the
roofline model (``repro.roofline.migration_transfer_s``) predicts the
transfer cost next to the measured one.  Decode output is bit-identical to
a run without the resize — migration moves state, never mutates it
(``verify_resharding`` checks every bucket against the plan's
permutation layout).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core import ElasticPlanner
from repro.models import decode_step, init_cache, init_params, prefill
from repro.roofline import migration_transfer_s
from repro.runtime import (
    DeviceBucketedState, ElasticController, JaxBackend, MigrationExecutor,
    route, verify_resharding,
)


@dataclass
class ServeResult:
    tokens: np.ndarray                 # [B, G+1] generated token ids
    step_s: List[float]                # per-decode-step wall seconds
    prefill_s: float
    req_bucket: np.ndarray             # [B] request -> bucket
    resize: Optional[Dict] = None      # metrics of the elastic event
    boundaries: List[int] = field(default_factory=list)

    @property
    def steady_s(self) -> float:
        """Median step time outside the resize step."""
        skip = self.resize["step"] if self.resize else -1
        other = [t for g, t in enumerate(self.step_s) if g != skip]
        return float(np.median(other)) if other else 0.0

    @property
    def spike_s(self) -> float:
        """Step time of the resize step (transfer + replan + decode)."""
        if not self.resize:
            return 0.0
        return float(self.step_s[self.resize["step"]])


def _decode_nodes(state: DeviceBucketedState, step_fn, params,
                  tok: np.ndarray, pos_val: int) -> np.ndarray:
    """One decode step across all serving nodes: each node decodes its own
    shard (padded rows included, masked out of the result)."""
    new_tok = tok.copy()
    pos = jnp.full((state.cap,), pos_val, jnp.int32)
    for i in state.node_ids():
        rows = state.row_req[i]
        valid = rows >= 0
        if not valid.any():
            continue
        safe = np.where(valid, rows, 0)
        tok_local = jnp.asarray(tok[safe])
        dev = state.device_of(i)
        if dev is not None:
            tok_local = jax.device_put(tok_local, dev)
        logits, shard = step_fn(params, state.shards[i], tok_local, pos)
        state.shards[i] = shard
        t_local = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
        new_tok[rows[valid]] = t_local[valid]
    return new_tok


def _do_resize(ctl: ElasticController, state: DeviceBucketedState,
               backend: JaxBackend, n_new: int, step: int,
               verify: bool) -> Dict:
    m = state.m
    w = np.bincount(state.req_bucket, minlength=m).astype(float) + 1e-9
    pre = state.to_host().buckets if verify else None
    n_before = ctl.n_nodes
    clock0, bytes0 = backend.clock, backend.bytes_moved
    t0 = time.perf_counter()
    plan, rep = ctl.scale(n_new, w, state)
    wall_s = time.perf_counter() - t0
    owner = ctl.assign.owner_of()
    routing_ok = bool(np.array_equal(owner[state.req_bucket],
                                     state.req_node))
    verified = False
    if verify:
        verify_resharding(plan, state, pre)   # raises on mismatch
        verified = True
    return {
        "step": step,
        "n_before": n_before,
        "n_after": ctl.n_nodes,
        "moves": rep.moves,
        "phases": rep.phases,
        "bytes_moved": backend.bytes_moved - bytes0,
        "plan_cost_bytes": float(plan.cost),
        "transfer_s_wall": backend.clock - clock0,
        "resize_s_wall": wall_s,
        "predicted_ici_s": migration_transfer_s(rep.phase_link_bytes, "ici"),
        "predicted_hbm_s": migration_transfer_s(rep.phase_link_bytes, "hbm"),
        "routing_ok": routing_ok,
        "verified": verified,
    }


def run_serving(arch: str = "qwen2.5-3b", smoke: bool = True,
                requests: int = 16, prompt_len: int = 24, gen: int = 16,
                buckets: int = 16, nodes: int = 2,
                resize: Optional[Tuple[int, int]] = None,
                tau: float = 0.2, cap: Optional[int] = None,
                seed: int = 0, verify: bool = True,
                quiet: bool = True) -> ServeResult:
    """Run the elastic serving loop; ``resize=(step, n_new)`` fires a live
    mid-decode elastic event that reshards the real KV cache."""
    cfg = get_smoke(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    B, P, G = requests, prompt_len, gen
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))

    m = buckets
    req_bucket = route(np.arange(B) + 1000, m)
    backend = JaxBackend()
    ctl = ElasticController(
        m, nodes, tau=tau, planner=ElasticPlanner(policy="ssm"),
        # verify=True also arms the pre-execution plan checker: a plan
        # violating the PLN catalog aborts before touching the live cache
        executor=MigrationExecutor(backend=backend, mode="live",
                                   verify="strict" if verify else None))

    cache = init_cache(cfg, B, P + G + 1)
    t0 = time.perf_counter()
    logits, cache = prefill(params, cfg, batch, cache)
    tok = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
    prefill_s = time.perf_counter() - t0
    if not quiet:
        print(f"prefill {B}×{P} in {prefill_s:.2f}s")

    # split the real cache into per-node device shards: THIS is the
    # operator state the elastic event migrates
    state = DeviceBucketedState.from_cache(
        cache, req_bucket, ctl.assign.owner_of(), cap=cap or B,
        devices=jax.devices())
    del cache

    step_fn = jax.jit(lambda p, c, t, pos: decode_step(
        cfg=cfg, params=p, cache=c, tokens=t, pos=pos))
    out_tokens = [tok]
    step_s: List[float] = []
    resize_info = None
    for g in range(G):
        t0 = time.perf_counter()
        if resize is not None and g == resize[0]:
            resize_info = _do_resize(ctl, state, backend, resize[1], g,
                                     verify)
            if not quiet:
                r = resize_info
                print(f"  elastic resize @step {g}: n {r['n_before']}→"
                      f"{r['n_after']}, moved {r['bytes_moved']/1e6:.2f}MB "
                      f"in {r['phases']} phases "
                      f"({r['transfer_s_wall']*1e3:.1f}ms measured, "
                      f"{r['predicted_ici_s']*1e3:.3f}ms roofline ICI)")
        tok = _decode_nodes(state, step_fn, params, tok, P + g)
        step_s.append(time.perf_counter() - t0)
        out_tokens.append(tok)
    if not quiet:
        dt = sum(step_s)
        print(f"decoded {G} steps × {B} reqs in {dt:.2f}s "
              f"({B*G/dt:.1f} tok/s)")
    gen_toks = np.concatenate(out_tokens, axis=1)
    bounds = [iv[0] for iv in ctl.assign.intervals if iv[1] > iv[0]]
    return ServeResult(tokens=gen_toks, step_s=step_s, prefill_s=prefill_s,
                       req_bucket=req_bucket, resize=resize_info,
                       boundaries=bounds)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--buckets", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--cap", type=int, default=None,
                    help="per-node row capacity (default: all requests)")
    ap.add_argument("--tau", type=float, default=0.2,
                    help="balance slack: per-node cap = (1+tau)·W/n")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-verify", dest="verify", action="store_false")
    ap.add_argument("--resize-at", default="",
                    help="step:n_new — live elastic event mid-decode")
    args = ap.parse_args(argv)

    resize = None
    if args.resize_at:
        a, b = args.resize_at.split(":")
        resize = (int(a), int(b))
    res = run_serving(arch=args.arch, smoke=args.smoke,
                      requests=args.requests, prompt_len=args.prompt_len,
                      gen=args.gen, buckets=args.buckets, nodes=args.nodes,
                      resize=resize, tau=args.tau, cap=args.cap,
                      seed=args.seed,
                      verify=args.verify, quiet=False)
    if res.resize:
        r = res.resize
        print(f"resize-step spike {res.spike_s*1e3:.1f}ms vs steady "
              f"{res.steady_s*1e3:.1f}ms/step; routing_ok={r['routing_ok']} "
              f"verified={r['verified']}")
    print("sample request 0 tokens:", res.tokens[0][:12])
    return res.tokens


if __name__ == "__main__":
    main()
