"""Serving driver: batched prefill + decode with a request router whose
KV state is bucketed operator state — the paper's technique keeps serving
replicas elastic.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --requests 16 --prompt-len 24 --gen 16 --resize-at 8:3

Requests are hashed into m buckets (repro.runtime.route); each serving node
owns a contiguous bucket interval.  ``--resize-at step:n`` triggers a live
elastic event mid-decode: SSM plans the minimal KV movement, the executor
phases it, and decoding continues (to-stay buckets never pause).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core import ElasticPlanner, TauSchedule
from repro.models import decode_step, init_cache, init_params, prefill
from repro.runtime import (
    BucketedState, ElasticController, MigrationExecutor, SimBackend, route,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--buckets", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--resize-at", default="",
                    help="step:n_new — live elastic event mid-decode")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, P, G = args.requests, args.prompt_len, args.gen
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))

    # route requests into buckets; the controller owns bucket placement
    m = args.buckets
    req_bucket = route(np.arange(B) + 1000, m)
    ctl = ElasticController(m, args.nodes,
                            planner=ElasticPlanner(
                                policy="ssm",
                                tau=TauSchedule(base=1.2, grow=0.3)),
                            executor=MigrationExecutor(
                                backend=SimBackend(bw_bytes_per_s=1e9),
                                mode="live"))
    resize_step, resize_n = -1, 0
    if args.resize_at:
        a, b = args.resize_at.split(":")
        resize_step, resize_n = int(a), int(b)

    cache = init_cache(cfg, B, P + G + 1)
    t0 = time.time()
    logits, cache = prefill(params, cfg, batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print(f"prefill {B}×{P} in {time.time()-t0:.2f}s")

    step_fn = jax.jit(lambda p, c, t, pos: decode_step(cfg=cfg, params=p,
                                                       cache=c, tokens=t,
                                                       pos=pos))
    out_tokens = [tok]
    # operator state for the controller: per-bucket KV bytes (est.)
    kv_bytes = np.zeros(m)
    per_req = sum(np.prod(v.shape[1:]) * v.dtype.itemsize
                  for v in jax.tree_util.tree_leaves(cache))
    for j in range(m):
        kv_bytes[j] = per_req * (req_bucket == j).sum()
    op_state = BucketedState([{"kv": np.zeros(max(int(kv_bytes[j] // 8), 1),
                                              np.float64)} for j in range(m)])
    t0 = time.time()
    for g in range(G):
        if g == resize_step:
            w = np.bincount(req_bucket, minlength=m).astype(float) + 1e-9
            plan, rep = ctl.scale(resize_n, w, op_state)
            print(f"  elastic resize @step {g}: n→{resize_n} moved "
                  f"{rep.bytes_moved/1e6:.1f}MB in {rep.phases} phases "
                  f"({rep.duration_s*1e3:.1f}ms simulated)")
        pos = jnp.full((B,), P + g, jnp.int32)
        logits, cache = step_fn(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    print(f"decoded {G} steps × {B} reqs in {dt:.2f}s "
          f"({B*G/dt:.1f} tok/s)")
    gen = jnp.concatenate(out_tokens, axis=1)
    print("sample request 0 tokens:", np.asarray(gen[0][:12]))
    return gen


if __name__ == "__main__":
    main()
