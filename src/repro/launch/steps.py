"""Train / prefill / serve step factories.

``make_train_step`` returns a pure function (params, opt_state, batch) ->
(params, opt_state, metrics); the data-parallel gradient mean is produced by
GSPMD from the loss mean (baseline), or — with ``grad_compression=True`` —
by an explicit int8 error-feedback all-gather inside a shard_map that is
manual over the data axes only (the model axis stays GSPMD-auto).

``make_serve_step`` returns (params, cache, tokens, pos) -> (logits, cache):
one decode step.  ``make_prefill_step`` fills the cache from a prompt batch.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.models import decode_step, loss_fn, prefill
from repro.models.config import ModelConfig
from repro.optim import (
    OptConfig, adamw_update, compressed_psum_mean, init_error_state,
)
from .mesh import data_axes


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, *,
                    schedule: str = "masked", microbatches: int = 1,
                    accum_dtype=jnp.float32) -> Callable:
    """Train step with optional microbatched gradient accumulation.

    ``microbatches > 1`` scans over batch slices, bounding live activation
    memory to one microbatch (the dry run showed mixtral train_4k needs
    this to fit v5e HBM); gradients accumulate in ``accum_dtype`` (f32
    default; bf16 halves the accumulator at a small precision cost).
    """
    def one_loss(params, batch):
        return loss_fn(params, cfg, batch, schedule=schedule, remat=True)

    if microbatches == 1:
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(one_loss)(params, batch)
            new_params, new_opt, metrics = adamw_update(
                grads, opt_state, params, opt_cfg)
            metrics["loss"] = loss
            return new_params, new_opt, metrics

        return train_step

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        assert B % microbatches == 0
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((microbatches, B // microbatches)
                                + x.shape[1:]), batch)

        def acc(carry, mb):
            g_acc, l_acc = carry
            loss, grads = jax.value_and_grad(one_loss)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(a.dtype), g_acc, grads)
            return (g_acc, l_acc + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (g, l), _ = jax.lax.scan(acc, (zeros, jnp.zeros((), jnp.float32)),
                                 mbs)
        g = jax.tree_util.tree_map(lambda x: x / microbatches, g)
        new_params, new_opt, metrics = adamw_update(
            g, opt_state, params, opt_cfg)
        metrics["loss"] = l / microbatches
        return new_params, new_opt, metrics

    return train_step


def make_compressed_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                               mesh: Mesh, param_spec_tree, batch_spec_tree,
                               *, schedule: str = "masked") -> Callable:
    """Train step whose DP gradient reduction is int8 + error feedback.

    shard_map is manual over the data axes only; parameters stay replicated
    w.r.t. data (spec P() on data axes) and the model axis remains auto.
    The optimizer state is data-replicated in this mode (the ZeRO-1 state
    sharding and wire compression are alternative memory/bandwidth
    trade-offs; see EXPERIMENTS.md §Perf).
    """
    daxes = data_axes(mesh)

    def body(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, schedule=schedule, remat=True)
        )(params)
        mean_grads, new_err = compressed_psum_mean(grads, err, daxes)
        loss = jax.lax.pmean(loss, daxes)
        new_params, new_opt, metrics = adamw_update(
            mean_grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, new_err, metrics

    # manual over data axes only: batch splits on axis 0, everything else is
    # data-replicated; the "model" axis is untouched (auto).
    def dspec(tree, batched: bool):
        def one(v):
            nd = v.ndim if hasattr(v, "ndim") else 0
            if batched and nd:
                return P(daxes if len(daxes) > 1 else daxes[0],
                         *([None] * (nd - 1)))
            return P(*([None] * nd))
        return jax.tree_util.tree_map(one, tree)

    def train_step(params, opt_state, err, batch):
        in_specs = (dspec(params, False), dspec(opt_state, False),
                    dspec(err, False), dspec(batch, True))
        out_specs = (dspec(params, False), dspec(opt_state, False),
                     dspec(err, False),
                     {"loss": P(), "grad_norm": P(), "lr": P()})
        f = shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, axis_names=set(daxes),
                      check_vma=False)
        return f(params, opt_state, err, batch)

    # partial-manual shard_map requires a surrounding jit (eager tracing
    # rejects auto axes in out_specs)
    return jax.jit(train_step)


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = decode_step(params, cfg, tokens, pos, cache)
        return logits, new_cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, *, schedule: str = "masked"
                      ) -> Callable:
    def prefill_step(params, batch, cache):
        logits, new_cache = prefill(params, cfg, batch, cache,
                                    schedule=schedule)
        return logits, new_cache

    return prefill_step
