"""Sharding rules: parameter, optimizer-state, batch and cache PartitionSpecs
for every architecture family on the production meshes.

TP plan (model axis):
* q heads column-parallel; K/V projections REPLICATED when Hkv < model-axis
  (the vLLM/Megatron GQA rule — avoids padded/uneven head shards); wo
  row-parallel (psum).
* MLP: w_gate/w_up column-, w_down row-parallel.
* MoE: experts over "model" when E % model == 0 (phi3.5: EP=16); otherwise
  TP *inside* experts over d_ff (mixtral 8e on 16-way: EP would pad 2×).
* RG-LRU: the whole recurrent path is sharded over lru blocks ("model"),
  zero collectives inside the recurrence; in/out projections col/row-parallel.
* Mamba: d_inner over "model" (elementwise scan path stays local), x_proj
  row-parallel into the small (dt,B,C) head, out_proj row-parallel.
* Embedding/unembedding over vocab.

DP/ZeRO-1 (data axes): gradients mean-reduced over ("pod","data"); optimizer
master/m/v additionally sharded over the data axes on the largest
still-unsharded divisible dimension.

Batch rule: batch dim over ("pod","data") — except long_500k (B=1), where
the KV/window cache shards its *sequence* dim over "data" (SP) instead.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShapeConfig
from .mesh import data_axes, data_size, model_size


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _attn_specs(cfg, mesh) -> Dict[str, P]:
    msz = model_size(mesh)
    kv_shardable = cfg.padded_kv_heads and cfg.padded_kv_heads % msz == 0
    q_shardable = cfg.padded_heads and cfg.padded_heads % msz == 0
    qs = "model" if q_shardable else None
    kvs = "model" if kv_shardable else None
    sp = {
        "wq": P(None, qs, None),
        "wk": P(None, kvs, None),
        "wv": P(None, kvs, None),
        "wo": P(qs, None, None),
        "bq": P(qs, None), "bk": P(kvs, None), "bv": P(kvs, None),
        "q_norm": P(None), "k_norm": P(None),
    }
    return sp


def _mlp_specs(cfg, mesh) -> Dict[str, P]:
    msz = model_size(mesh)
    ff = "model" if cfg.d_ff and cfg.d_ff % msz == 0 else None
    return {"w_gate": P(None, ff), "w_up": P(None, ff),
            "w_gu": P(None, None, ff), "w_down": P(ff, None)}


def _moe_specs(cfg, mesh) -> Dict[str, P]:
    msz = model_size(mesh)
    if cfg.n_experts % msz == 0:
        e = ("model", None, None)
    else:
        # TP inside experts instead of padded EP
        assert cfg.d_ff % msz == 0
        e = None
    if e:
        return {"router": P(None, None), "w_gate": P(*e), "w_up": P(*e),
                "w_gu": P("model", None, None, None), "w_down": P(*e)}
    return {"router": P(None, None),
            "w_gate": P(None, None, "model"),
            "w_up": P(None, None, "model"),
            "w_gu": P(None, None, None, "model"),
            "w_down": P(None, "model", None)}


def _rglru_specs(cfg, mesh) -> Dict[str, P]:
    msz = model_size(mesh)
    ok = cfg.d_lru % msz == 0 and max(cfg.n_heads, 1) % msz == 0
    m = "model" if ok else None
    return {
        "w1": P(None, m), "w2": P(None, m), "conv": P(None, m),
        "wa": P(m, None, None), "wx": P(m, None, None),
        "lam": P(m), "w_out": P(m, None),
    }


def _mamba_specs(cfg, mesh) -> Dict[str, P]:
    msz = model_size(mesh)
    ok = cfg.d_inner % msz == 0
    m = "model" if ok else None
    return {
        "in_proj": P(None, m), "conv": P(None, m),
        "x_proj": P(m, None), "dt_proj": P(None, m), "dt_bias": P(m),
        "A_log": P(m, None), "D": P(m), "out_proj": P(m, None),
    }


def _norm_spec(leaf) -> P:
    return P(*([None] * np.ndim(leaf)))


def _layer_specs(cfg, mesh, p_layer) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for k, v in p_layer.items():
        if k == "attn" or k == "cross_attn":
            sp = _attn_specs(cfg, mesh)
            out[k] = {kk: sp[kk] for kk in v}
        elif k == "mlp":
            sp = _mlp_specs(cfg, mesh)
            out[k] = {kk: sp[kk] for kk in v}
        elif k == "moe":
            sp = _moe_specs(cfg, mesh)
            out[k] = {kk: sp[kk] for kk in v}
        elif k == "rglru":
            sp = _rglru_specs(cfg, mesh)
            out[k] = {kk: sp[kk] for kk in v}
        elif k == "mamba":
            sp = _mamba_specs(cfg, mesh)
            out[k] = {kk: sp[kk] for kk in v}
        else:  # norms (possibly dicts for ln)
            out[k] = jax.tree_util.tree_map(_norm_spec, v)
    return out


def _prepend(spec_tree, axis=None):
    """Add a leading (stacked-layer) dim to every spec."""
    return jax.tree_util.tree_map(
        lambda s: P(axis, *s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    """PartitionSpec pytree matching init_params' structure.

    ``params_shape`` is the eval_shape pytree (structure source)."""
    msz = model_size(mesh)
    vs = "model" if cfg.vocab_size % msz == 0 else None
    specs: Dict[str, Any] = {}
    for k, v in params_shape.items():
        if k in ("embed", "unembed"):
            specs[k] = P(vs, None)
        elif k == "final_norm":
            specs[k] = jax.tree_util.tree_map(_norm_spec, v)
        elif k == "blocks":
            specs[k] = tuple(
                _prepend(_layer_specs(cfg, mesh, _strip_stack(group)))
                for group in v
            )
        elif k == "tail":
            specs[k] = tuple(_layer_specs(cfg, mesh, g) for g in v)
        elif k == "encoder":
            specs[k] = {
                "blocks": _prepend(
                    _layer_specs(cfg, mesh, _strip_stack(v["blocks"]))),
                "final_norm": jax.tree_util.tree_map(
                    _norm_spec, v["final_norm"]),
            }
        else:
            raise KeyError(k)
    return specs


def _strip_stack(group):
    """View a stacked layer-params pytree as a single layer (drop lead dim)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), group)


# ---------------------------------------------------------------------------
# Optimizer-state specs: ZeRO-1 over the data axes
# ---------------------------------------------------------------------------

def zero1_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Extend a param spec with the data axes on the largest unsharded,
    divisible dim (classic optimizer-state sharding)."""
    daxes = data_axes(mesh)
    dsz = data_size(mesh)
    if not daxes or dsz == 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # pick the largest unsharded dim divisible by the data size
    best, best_dim = -1, -1
    for i, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % dsz == 0 and s > best:
            best, best_dim = s, i
    if best_dim < 0:
        return spec
    entries[best_dim] = daxes if len(daxes) > 1 else daxes[0]
    return P(*entries)


def opt_state_specs(param_specs_tree, params_shape, mesh: Mesh) -> Any:
    """Specs for {"step","master","m","v"} given param specs/shapes."""
    def z(spec, shp):
        return zero1_spec(spec, shp.shape, mesh)

    zt = jax.tree_util.tree_map(
        z, param_specs_tree, params_shape,
        is_leaf=lambda s: isinstance(s, P))
    return {"step": P(), "master": zt, "m": zt, "v": zt}


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_shape) -> Any:
    daxes = data_axes(mesh)
    dp = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def spec_for(k, v):
        if np.ndim(v) == 0:
            return P()
        if v.shape[0] % max(data_size(mesh), 1) != 0:
            return P(*([None] * np.ndim(v)))       # unshardable tiny batch
        return P(dp, *([None] * (np.ndim(v) - 1)))

    return {k: spec_for(k, v) for k, v in batch_shape.items()}


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape,
                seq_shard: bool = False) -> Any:
    """Decode-cache specs.  Batch over data axes; when ``seq_shard`` (the
    long_500k B=1 cell) KV/window sequence dim goes over "data" instead and
    recurrent channel dims go over "model"."""
    daxes = data_axes(mesh)
    dp = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    msz = model_size(mesh)
    dsz = data_size(mesh)

    def leaf_spec(path, v):
        names = [getattr(x, "key", getattr(x, "name", str(x))) for x in path]
        nd = np.ndim(v)
        stacked = "blocks" in names or "cross_k" in names or (
            "cross_v" in names)
        off = 1 if stacked else 0            # leading [reps]/[L] dim
        shape = v.shape
        batch_ok = shape[off] % max(dsz, 1) == 0

        def base(*rest):
            pre = (None,) * off
            return P(*(pre + rest))

        if "k" in names or "v" in names or "cross_k" in names or (
                "cross_v" in names):
            # [.., B, S, Hkv, hd]
            kvs = "model" if (cfg.padded_kv_heads
                              and cfg.padded_kv_heads % msz == 0) else None
            # when heads can't take the model axis, the cache SEQUENCE dim
            # must (flash-decode style): otherwise a 32k cache is 34 GB per
            # device and blows the HBM budget (memory_analysis catches it)
            s_sh = None
            if kvs is None and shape[off + 1] % msz == 0:
                s_sh = "model"
            if seq_shard and shape[off + 1] % max(dsz, 1) == 0:
                dd = daxes if len(daxes) > 1 else daxes[0]
                s_sh = (dd if s_sh is None else
                        (tuple(daxes) + ("model",)
                         if shape[off + 1] % (dsz * msz) == 0 else dd))
                return base(None, s_sh, kvs, None)
            return base(dp if batch_ok else None, s_sh, kvs, None)
        if "pos" in names:
            s_sh = None
            if (not (cfg.padded_kv_heads
                     and cfg.padded_kv_heads % msz == 0)
                    and shape[off + 1] % msz == 0):
                s_sh = "model"
            if seq_shard and shape[off + 1] % max(dsz, 1) == 0:
                dd = daxes if len(daxes) > 1 else daxes[0]
                s_sh = (dd if s_sh is None else
                        (tuple(daxes) + ("model",)
                         if shape[off + 1] % (dsz * msz) == 0 else dd))
                return base(None, s_sh)
            return base(dp if batch_ok else None, s_sh)
        if "h" in names:
            # rglru [.., B, dl] / mamba [.., B, di, N]
            ch = shape[off + 1]
            ms = "model" if ch % msz == 0 else None
            rest = (ms,) + (None,) * (nd - off - 2)
            return base(dp if batch_ok else None, *rest)
        if "conv" in names:
            ch = shape[off + 2]
            ms = "model" if ch % msz == 0 else None
            return base(dp if batch_ok else None, None, ms)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


def elastic_cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape,
                        axis: str = "data") -> Any:
    """Decode-cache specs for the elastic serving path: the REQUEST axis of
    every leaf goes over ``axis`` (nodes = mesh slices along it), everything
    else replicated.  Which axis is the request axis comes from the same
    rule ``DeviceBucketedState`` uses (``runtime.state.cache_batch_axis``:
    stacked ``blocks``/``cross_k``/``cross_v`` leaves carry batch at axis 1,
    ``tail`` leaves at axis 0) — the GSPMD counterpart of the per-node
    shard layout, for the collective-migration dry run.  Leaves whose
    request dim doesn't divide the axis size stay replicated (GSPMD would
    otherwise pad unevenly)."""
    from repro.runtime.state import cache_batch_axis
    asz = int(np.prod([mesh.shape[a] for a in (
        axis if isinstance(axis, tuple) else (axis,))]))

    def leaf_spec(path, v):
        names = [str(getattr(x, "key", getattr(x, "name",
                                               getattr(x, "idx", x))))
                 for x in path]
        ax = cache_batch_axis(names)
        nd = np.ndim(v)
        entries = [None] * nd
        if nd > ax and v.shape[ax] % max(asz, 1) == 0:
            entries[ax] = axis
        return P(*entries)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


def to_named(tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))
