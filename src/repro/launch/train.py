"""Training driver.

Runs a real training loop on whatever devices exist (CPU smoke configs in
this container; the production meshes via --mesh data,model on a pod):

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
        --steps 50 --batch 8 --seq 64

Features exercised: synthetic deterministic data pipeline (restart-safe),
AdamW + cosine schedule, periodic async checkpointing, checkpoint-restart
(--resume), and step-time tracking feeding the straggler detector.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.shardings import (
    batch_specs, opt_state_specs, param_specs, to_named,
)
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import OptConfig, init_opt_state
from repro.runtime.checkpoint import _flatten, _unflatten


def save_train_ckpt(path: Path, step: int, params, opt_state):
    path.mkdir(parents=True, exist_ok=True)
    flat = _flatten({"params": jax.device_get(params),
                     "opt": jax.device_get(opt_state)})
    # bf16 (ml_dtypes, numpy kind 'V') is not npz-storable: widen to f32
    flat = {k: (v.astype(np.float32) if v.dtype.kind == "V" else v)
            for k, v in flat.items()}
    np.savez(path / f"train_{step}.npz", **flat)
    (path / "latest.json").write_text(json.dumps({"step": step}))


def load_train_ckpt(path: Path, proto):
    meta = json.loads((path / "latest.json").read_text())
    flat = dict(np.load(path / f"train_{meta['step']}.npz"))
    tree = _unflatten(flat, {"params": proto["params"],
                             "opt": proto["opt"]})
    # restore original dtypes (bf16 params round-trip via f32)
    tree = jax.tree_util.tree_map(
        lambda a, p: jnp.asarray(a, dtype=p.dtype), tree, proto)
    return meta["step"], tree["params"], tree["opt"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="",
                    help="e.g. '16,16' for (data,model); default: all "
                         "devices on data")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
    else:
        mesh = make_mesh((n_dev, 1), ("data", "model"))

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt_state = init_opt_state(params)
    start_step = 0
    ckpt = Path(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and ckpt and (ckpt / "latest.json").exists():
        start_step, params, opt_state = load_train_ckpt(
            ckpt, {"params": params, "opt": opt_state})
        print(f"resumed from step {start_step}")

    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                     global_batch=args.batch)
    params_s = jax.eval_shape(lambda: params)
    pspecs = param_specs(cfg, mesh, params_s)
    pshard = to_named(pspecs, mesh)
    oshard = to_named(opt_state_specs(pspecs, params_s, mesh), mesh)
    b0 = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
          for k, v in ds.batch_at(0).items()}
    bshard = to_named(batch_specs(cfg, mesh, b0), mesh)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg),
                      in_shardings=(pshard, oshard, bshard),
                      out_shardings=(pshard, oshard, None),
                      donate_argnums=(0, 1))

    params = jax.device_put(params, pshard)
    opt_state = jax.device_put(opt_state, oshard)
    losses = []
    with mesh:
        for step in range(start_step, args.steps):
            batch = jax.device_put(ds.batch_at(step), bshard)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms",
                      flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                save_train_ckpt(ckpt, step + 1, params, opt_state)
    if ckpt:
        save_train_ckpt(ckpt, args.steps, params, opt_state)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
