"""Pure-JAX building blocks shared by every assigned architecture.

Everything here is functional (params are explicit pytree leaves) and
GSPMD-friendly: no host callbacks, static shapes, `lax.scan` for long loops
so the HLO stays small enough to compile 64-layer models against 512
placeholder devices.

Attention comes in three schedules (all pure jnp; the Pallas kernels in
``repro.kernels`` implement the same schedules for TPU):

* ``masked``  — scan over KV blocks with a causal mask.  Simple, but causal
                masking wastes ~2× FLOPs at long sequence.  Baseline.
* ``folded``  — causal-folded schedule: q-blocks i and nq-1-i are processed
                together so every scan step does exactly one block matmul and
                total block-pairs = nq(nq+1)/2, i.e. *honest* causal FLOPs.
                Used by the perf-optimized configs (EXPERIMENTS.md §Perf).
* ``banded``  — sliding/local window: each q-block attends a fixed-size KV
                band gathered with a dynamic slice ⇒ O(S·window) compute.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(F32)
    y = x * lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(F32))
    return y.astype(dt)


def layer_norm(x: jax.Array, scale: Optional[jax.Array],
               bias: Optional[jax.Array], eps: float = 1e-5):
    """LayerNorm; pass scale=bias=None for OLMo's non-parametric LN."""
    dt = x.dtype
    x = x.astype(F32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(F32)
    if bias is not None:
        y = y + bias.astype(F32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_sincos(positions: jax.Array, head_dim: int, theta: float):
    """positions [...,] -> (sin, cos) of shape [..., head_dim/2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=F32) / half))
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array):
    """x [..., S, n_heads, head_dim]; sin/cos broadcastable to [..., S, 1, hd/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    dt = x.dtype
    x1, x2 = x1.astype(F32), x2.astype(F32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(dt)


# ---------------------------------------------------------------------------
# Attention projections
# ---------------------------------------------------------------------------

def qkv_project(x, p, cfg, positions):
    """x [B,S,d] -> q [B,S,H,hd], k,v [B,S,Hkv,hd] (roped q,k)."""
    pet = reduce_pet(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=pet).astype(F32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"],
                   preferred_element_type=pet).astype(F32)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"],
                   preferred_element_type=pet).astype(F32)
    if "bq" in p:
        q = q + p["bq"].astype(F32)
        k = k + p["bk"].astype(F32)
        v = v + p["bv"].astype(F32)
    if "q_norm" in p:  # qwen3-style per-head RMSNorm on q/k
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    sin, cos = rope_sincos(positions, q.shape[-1], cfg.rope_theta)
    q = apply_rope(q.astype(x.dtype), sin, cos)
    k = apply_rope(k.astype(x.dtype), sin, cos)
    return q, k, v.astype(x.dtype)


def reduce_pet(cfg):
    """Output dtype of ROW-PARALLEL matmuls (the all-reduced ones): bf16
    when cfg.bf16_reduce — halves the TP activation all-reduce bytes
    (EXPERIMENTS.md §Perf); accumulation stays f32 inside the MXU."""
    return jnp.bfloat16 if getattr(cfg, "bf16_reduce", False) else F32


def out_project(o, p, cfg=None):
    pet = reduce_pet(cfg) if cfg is not None else F32
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"], preferred_element_type=pet
                      ).astype(o.dtype)


# ---------------------------------------------------------------------------
# Blocked attention (training / prefill)
# ---------------------------------------------------------------------------

def _online_combine(m, s, acc, scores, v_blk):
    """One online-softmax step.  scores [B,Hkv,G,qb,kb] f32,
    v_blk [B,Hkv,kb,hd]."""
    m_new = jnp.maximum(m, scores.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(scores - m_new[..., None])
    s_new = s * alpha + p.sum(axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(v_blk.dtype), v_blk,
        preferred_element_type=F32,
    )
    return m_new, s_new, acc_new


def _split_heads(q, k, v):
    """[B,S,H,hd]/[B,S,Hkv,hd] -> grouped [B,Hkv,G,S,hd], [B,Hkv,S,hd]."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    return qg, kg, vg


def _merge_heads(o):
    """[B,Hkv,G,S,hd] -> [B,S,H,hd]."""
    B, Hkv, G, S, hd = o.shape
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, Hkv * G, hd)


NEG_INF = -1e30


def blocked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    kv_block: int = 512,
    schedule: str = "masked",          # masked | folded | auto
) -> jax.Array:
    """FlashAttention-style streaming attention in pure jnp.

    q [B,Sq,H,hd]; k,v [B,Skv,Hkv,hd]; GQA via head grouping.  Sq == Skv is
    assumed for causal (self-attention); cross-attention passes causal=False.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    if Sq % q_block or Skv % kv_block:
        # fall back to one-shot reference for ragged tiny shapes (smoke tests)
        return attention_reference(q, k, v, causal=causal, window=window)
    nq, nk = Sq // q_block, Skv // kv_block
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qg, kg, vg = _split_heads(q, k, v)
    qb = qg.reshape(B, qg.shape[1], qg.shape[2], nq, q_block, hd)
    kb = kg.reshape(B, kg.shape[1], nk, kv_block, hd)
    vb = vg.reshape(B, vg.shape[1], nk, kv_block, hd)

    if window and causal and Sq == Skv:
        out = _banded(qb, kb, vb, window, q_block, kv_block, scale)
    elif causal and Sq == Skv and schedule == "folded" and nq % 2 == 0:
        out = _folded_causal(qb, kb, vb, q_block, kv_block, scale)
    else:
        out = _masked(qb, kb, vb, causal and Sq == Skv, q_block, kv_block,
                      scale)
    return _merge_heads(out.reshape(B, out.shape[1], out.shape[2], Sq, hd))


def _masked(qb, kb, vb, causal, q_blk, kv_blk, scale):
    """Scan over q blocks; inner scan over all kv blocks with mask."""
    B, Hkv, G, nq, qblk, hd = qb.shape
    nk = kb.shape[2]

    def per_q(qi, q_tile):
        q_tile = q_tile * scale

        def step(carry, inp):
            m, s, acc = carry
            ji, k_tile, v_tile = inp
            scores = jnp.einsum("bhgqd,bhkd->bhgqk", q_tile, k_tile,
                                preferred_element_type=F32)
            if causal:
                qpos = qi * qblk + jnp.arange(qblk)
                kpos = ji * kv_blk + jnp.arange(kv_blk)
                mask = qpos[:, None] >= kpos[None, :]
                scores = jnp.where(mask, scores, NEG_INF)
            return _online_combine(m, s, acc, scores, v_tile), None

        init = (
            jnp.full((B, Hkv, G, qblk), NEG_INF, F32),
            jnp.zeros((B, Hkv, G, qblk), F32),
            jnp.zeros((B, Hkv, G, qblk, hd), F32),
        )
        (m, s, acc), _ = lax.scan(
            step, init,
            (jnp.arange(nk), kb.transpose(2, 0, 1, 3, 4),
             vb.transpose(2, 0, 1, 3, 4)),
        )
        return acc / jnp.maximum(s, 1e-30)[..., None]

    out = lax.map(lambda t: per_q(t[0], t[1]),
                  (jnp.arange(nq), qb.transpose(3, 0, 1, 2, 4, 5)))
    return out.transpose(1, 2, 3, 0, 4, 5).astype(kb.dtype)


def _folded_causal(qb, kb, vb, q_blk, kv_blk, scale):
    """Causal-folded schedule: q blocks (i, nq-1-i) share one KV sweep of
    nq+1 steps, each step exactly one block matmul ⇒ total pairs
    nq(nq+1)/2 — no masked-out waste."""
    B, Hkv, G, nq, qblk, hd = qb.shape
    nk = kb.shape[2]
    assert nq == nk and nq % 2 == 0
    half = nq // 2

    def per_pair(i):
        lo, hi = i, nq - 1 - i
        q_lo = qb[:, :, :, lo] * scale
        q_hi = qb[:, :, :, hi] * scale

        def step(carry, j):
            (ml, sl, al), (mh, sh, ah) = carry
            is_lo = j <= lo
            kv_idx = jnp.where(is_lo, j, j - lo - 1)
            k_tile = lax.dynamic_index_in_dim(kb, kv_idx, 2, keepdims=False)
            v_tile = lax.dynamic_index_in_dim(vb, kv_idx, 2, keepdims=False)
            q_tile = jnp.where(is_lo, q_lo, q_hi)
            qi = jnp.where(is_lo, lo, hi)
            scores = jnp.einsum("bhgqd,bhkd->bhgqk", q_tile, k_tile,
                                preferred_element_type=F32)
            # only the diagonal block needs the triangular mask
            qpos = qi * qblk + jnp.arange(qblk)
            kpos = kv_idx * kv_blk + jnp.arange(kv_blk)
            mask = qpos[:, None] >= kpos[None, :]
            diag = kv_idx == qi
            scores = jnp.where(jnp.logical_or(~diag, mask), scores, NEG_INF)
            m, s, acc = jnp.where(is_lo, ml, mh), jnp.where(is_lo, sl, sh), (
                jnp.where(is_lo, al, ah))
            m2, s2, a2 = _online_combine(m, s, acc, scores, v_tile)
            new_lo = (jnp.where(is_lo, m2, ml), jnp.where(is_lo, s2, sl),
                      jnp.where(is_lo, a2, al))
            new_hi = (jnp.where(is_lo, mh, m2), jnp.where(is_lo, sh, s2),
                      jnp.where(is_lo, ah, a2))
            return (new_lo, new_hi), None

        zero = (
            jnp.full((B, Hkv, G, qblk), NEG_INF, F32),
            jnp.zeros((B, Hkv, G, qblk), F32),
            jnp.zeros((B, Hkv, G, qblk, hd), F32),
        )
        ((ml, sl, al), (mh, sh, ah)), _ = lax.scan(
            step, (zero, zero), jnp.arange(nq + 1))
        o_lo = al / jnp.maximum(sl, 1e-30)[..., None]
        o_hi = ah / jnp.maximum(sh, 1e-30)[..., None]
        return o_lo, o_hi

    o_lo, o_hi = lax.map(per_pair, jnp.arange(half))   # [half,B,Hkv,G,qblk,hd]
    o_lo = o_lo.transpose(1, 2, 3, 0, 4, 5)
    o_hi = o_hi.transpose(1, 2, 3, 0, 4, 5)[:, :, :, ::-1]
    return jnp.concatenate([o_lo, o_hi], axis=3).astype(kb.dtype)


def _banded(qb, kb, vb, window, q_blk, kv_blk, scale):
    """Sliding-window causal attention: q block i attends KV rows
    [i*qb - window, i*qb + qb) gathered via dynamic slice ⇒ O(S·window)."""
    B, Hkv, G, nq, qblk, hd = qb.shape
    nk = kb.shape[2]
    Skv = nk * kv_blk
    band = window + qblk                      # static band size in rows
    band = -(-band // kv_blk) * kv_blk
    band = min(band, Skv)
    kf = kb.reshape(B, Hkv, Skv, hd)
    vf = vb.reshape(B, Hkv, Skv, hd)

    def per_q(i, q_tile):
        q_tile = q_tile * scale
        start = jnp.clip(i * qblk + qblk - band, 0, Skv - band)
        k_band = lax.dynamic_slice_in_dim(kf, start, band, axis=2)
        v_band = lax.dynamic_slice_in_dim(vf, start, band, axis=2)
        scores = jnp.einsum("bhgqd,bhkd->bhgqk", q_tile, k_band,
                            preferred_element_type=F32)
        qpos = i * qblk + jnp.arange(qblk)
        kpos = start + jnp.arange(band)
        mask = (qpos[:, None] >= kpos[None, :]) & (
            kpos[None, :] > qpos[:, None] - window)
        scores = jnp.where(mask, scores, NEG_INF)
        m = scores.max(axis=-1, keepdims=True)
        p = jnp.exp(scores - m)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v_band.dtype), v_band,
                       preferred_element_type=F32)
        return o / jnp.maximum(p.sum(axis=-1), 1e-30)[..., None]

    out = lax.map(lambda t: per_q(t[0], t[1]),
                  (jnp.arange(nq), qb.transpose(3, 0, 1, 2, 4, 5)))
    return out.transpose(1, 2, 3, 0, 4, 5).astype(kb.dtype)


def attention_reference(q, k, v, *, causal=True, window=0,
                        kv_positions=None, q_positions=None):
    """One-shot reference attention (oracle for kernels + tiny shapes).

    kv_positions/q_positions allow ring-buffer caches: masking is computed
    from absolute positions instead of array index.
    """
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=F32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qpos = (jnp.arange(Sq) if q_positions is None else q_positions)
    kpos = (jnp.arange(Skv) if kv_positions is None else kv_positions)
    qpos = jnp.asarray(qpos)
    kpos = jnp.asarray(kpos)
    if qpos.ndim == 1:
        qpos = qpos[None, :]
    if kpos.ndim == 1:
        kpos = kpos[None, :]
    mask = jnp.ones((qpos.shape[0], 1, 1, qpos.shape[1], kpos.shape[1]),
                    bool)
    if causal:
        mask &= (qpos[:, None, None, :, None] >= kpos[:, None, None, None, :])
    if window:
        mask &= (kpos[:, None, None, None, :]
                 > qpos[:, None, None, :, None] - window)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=F32)
    return o.reshape(B, Sq, H, hd).astype(v.dtype)


# ---------------------------------------------------------------------------
# Decode attention (one new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, q_pos, kv_positions):
    """q [B,1,H,hd]; caches [B,S,Hkv,hd]; kv_positions [B,S] absolute
    positions (-1 ⇒ invalid slot, e.g. unwritten ring-buffer entries)."""
    B, _, H, hd = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                        preferred_element_type=F32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    valid = (kv_positions >= 0) & (kv_positions <= q_pos[:, None])
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=F32)
    return o.reshape(B, 1, H, hd).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_swiglu(x, p, pet=F32):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"], preferred_element_type=pet)
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"], preferred_element_type=pet)
    h = (jax.nn.silu(g.astype(F32)) * u.astype(F32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"],
                      preferred_element_type=pet).astype(x.dtype)


def mlp_gelu(x, p, pet=F32):
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"], preferred_element_type=pet)
    h = jax.nn.gelu(h.astype(F32), approximate=True).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"],
                      preferred_element_type=pet).astype(x.dtype)


def mlp_swiglu_fused(x, p, pet=F32):
    # w_gu [d, 2, ff]: gate/up split along the UNSHARDED middle dim so the
    # slice never crosses ff shards (a [d, 2ff] layout would)
    gu = jnp.einsum("bsd,dtf->bstf", x, p["w_gu"], preferred_element_type=pet)
    g, u = gu[..., 0, :], gu[..., 1, :]
    h = (jax.nn.silu(g.astype(F32)) * u.astype(F32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"],
                      preferred_element_type=pet).astype(x.dtype)


def mlp(x, p, cfg):
    pet = reduce_pet(cfg)
    if "w_gu" in p:
        return mlp_swiglu_fused(x, p, pet)
    return mlp_swiglu(x, p, pet) if cfg.act == "silu" else \
        mlp_gelu(x, p, pet)


# ---------------------------------------------------------------------------
# Mixture of Experts (grouped capacity dispatch, GShard/Switch style)
# ---------------------------------------------------------------------------

def moe_apply(x, p, cfg, *, group_size: int = 1024,
              min_capacity: int = 1):
    """Top-k expert routing with per-group capacity.

    x [B,S,d].  Tokens are flattened and split into groups of ``group_size``;
    each group dispatches into every expert with capacity
    C = ceil(cf·top_k·group/E).  Dispatch/combine are one-hot einsums, which
    shard cleanly under GSPMD (tokens over data axes, experts over model).
    Overflow tokens are dropped (standard capacity-based MoE; cf=1.25).
    Decode passes ``min_capacity=group`` so single-token steps never drop.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    g = min(group_size, T)
    n_groups = T // g
    assert n_groups * g == T, f"group_size {g} must divide tokens {T}"
    cap = max(min_capacity, int(cfg.capacity_factor * K * g / E))

    xt = x.reshape(n_groups, g, d)
    logits = jnp.einsum("ngd,de->nge", xt, p["router"],
                        preferred_element_type=F32)
    topv, topi = lax.top_k(logits, K)                    # [n,g,K]
    gates = jax.nn.softmax(topv, axis=-1)                # renormalized top-k

    # dispatch/combine tensors hold 0/1 and gate weights: bf16 is lossless
    # for the one-hots and halves their HBM footprint (under bf16_reduce)
    ddt = reduce_pet(cfg)
    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(topi, E, dtype=F32)          # [n,g,K,E]
    flat = onehot.reshape(n_groups, g * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(n_groups, g, K, E)
    pos = jnp.einsum("ngke,ngke->ngk", pos, onehot).astype(jnp.int32)
    keep = pos < cap
    gates = gates * keep

    # dispatch [n, g, E, cap] combine weights
    slot_oh = jax.nn.one_hot(pos, cap, dtype=ddt)        # [n,g,K,cap]
    dispatch = jnp.einsum("ngke,ngkc->ngec",
                          (onehot * keep[..., None]).astype(ddt), slot_oh,
                          preferred_element_type=ddt)
    combine = jnp.einsum("ngk,ngke,ngkc->ngec", gates.astype(ddt),
                         onehot.astype(ddt), slot_oh,
                         preferred_element_type=ddt)

    x_e = jnp.einsum("ngec,ngd->necd", dispatch, xt.astype(ddt),
                     preferred_element_type=ddt)
    x_e = x_e.transpose(1, 0, 2, 3).reshape(E, n_groups * cap, d).astype(
        x.dtype)
    # x_e [E, n*cap, d] — run every expert's FFN
    pet = reduce_pet(cfg)
    if "w_gu" in p:
        gu = jnp.einsum("ecd,edtf->ectf", x_e, p["w_gu"],
                        preferred_element_type=pet)
        ge, ue = gu[..., 0, :], gu[..., 1, :]
    else:
        ge = jnp.einsum("ecd,edf->ecf", x_e, p["w_gate"],
                        preferred_element_type=pet)
        ue = jnp.einsum("ecd,edf->ecf", x_e, p["w_up"],
                        preferred_element_type=pet)
    he = (jax.nn.silu(ge.astype(F32)) * ue.astype(F32)).astype(x.dtype)
    oe = jnp.einsum("ecf,efd->ecd", he, p["w_down"],
                    preferred_element_type=pet)            # [E, n*cap, d]
    oe = oe.reshape(E, n_groups, cap, d).transpose(1, 0, 2, 3)
    # keep oe in its (possibly bf16) dtype INTO the combine so the TP psum
    # on the w_down output is not widened back to f32 by a hoisted convert
    out = jnp.einsum("ngec,necd->ngd", combine.astype(oe.dtype), oe,
                     preferred_element_type=F32)
    return out.reshape(B, S, d).astype(x.dtype), logits


def moe_apply_manual(x, p, cfg, *, group_size: int = 1024,
                     min_capacity: int = 1):
    """moe_apply with the expert FFN under a MANUAL shard_map over "model".

    GSPMD pins the TP activation all-reduce to the dot accumulation dtype
    (f32) regardless of preferred_element_type (measured — EXPERIMENTS.md
    §Perf); in manual mode the psum runs on whatever dtype we hand it, so
    the combine reduction crosses the wire in bf16: 2× fewer bytes.  The
    routing (top-k, capacity, dispatch/combine weights) stays in auto mode.
    """
    from repro.compat import ambient_mesh
    mesh = ambient_mesh()
    if not getattr(cfg, "manual_moe", False) or \
            "model" not in tuple(getattr(mesh, "axis_names", ()) or ()):
        return moe_apply(x, p, cfg, group_size=group_size,
                         min_capacity=min_capacity)
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    g = min(group_size, T)
    n_groups = T // g
    assert n_groups * g == T
    cap = max(min_capacity, int(cfg.capacity_factor * K * g / E))
    xt = x.reshape(n_groups, g, d)
    logits = jnp.einsum("ngd,de->nge", xt, p["router"],
                        preferred_element_type=F32)
    topv, topi = lax.top_k(logits, K)
    gates = jax.nn.softmax(topv, axis=-1)
    onehot = jax.nn.one_hot(topi, E, dtype=F32)
    flat = onehot.reshape(n_groups, g * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(n_groups, g, K, E)
    pos = jnp.einsum("ngke,ngke->ngk", pos, onehot).astype(jnp.int32)
    keep = pos < cap
    gates = gates * keep
    slot_oh = jax.nn.one_hot(pos, cap, dtype=F32)
    dispatch = jnp.einsum("ngke,ngkc->ngec", onehot * keep[..., None],
                          slot_oh)
    combine = jnp.einsum("ngk,ngke,ngkc->ngec", gates, onehot, slot_oh)
    x_e = jnp.einsum("ngec,ngd->necd", dispatch, xt.astype(F32))
    x_e = x_e.transpose(1, 0, 2, 3).reshape(E, n_groups * cap, d).astype(
        x.dtype)

    def expert_ffn(x_e_l, wg, wu, wd, comb):
        # local ff shard; explicit bf16 psum on the combined output
        ge = jnp.einsum("ecd,edf->ecf", x_e_l, wg,
                        preferred_element_type=F32)
        ue = jnp.einsum("ecd,edf->ecf", x_e_l, wu,
                        preferred_element_type=F32)
        he = (jax.nn.silu(ge) * ue).astype(x_e_l.dtype)
        oe = jnp.einsum("ecf,efd->ecd", he, wd,
                        preferred_element_type=F32)      # partial sums
        oe = oe.reshape(E, n_groups, cap, d).transpose(1, 0, 2, 3)
        out = jnp.einsum("ngec,necd->ngd", comb.astype(jnp.bfloat16),
                         oe.astype(jnp.bfloat16),
                         preferred_element_type=jnp.bfloat16)
        return lax.psum(out, "model")                    # bf16 on the wire

    from repro.compat import shard_map
    f = shard_map(
        expert_ffn,
        mesh=mesh,
        in_specs=(P(), P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None), P()),
        out_specs=P(),
        axis_names={"model"},
        check_vma=False,
    )
    out = f(x_e, p["w_gate"], p["w_up"], p["w_down"], combine)
    return out.reshape(B, S, d).astype(x.dtype), logits


def moe_aux_loss(logits: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balancing auxiliary loss over router logits."""
    probs = jax.nn.softmax(logits.astype(F32), axis=-1)    # [n,g,E]
    E = cfg.n_experts
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, E, dtype=F32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(frac_tokens * frac_probs)
