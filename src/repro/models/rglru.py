"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (one "rglru" layer of the hybrid pattern):

    x ──► W1 ──► GeLU ─────────────────────────┐
    x ──► W2 ──► causal conv1d ──► RG-LRU ──► ⊙ ──► W_out

RG-LRU recurrence (per channel):
    r_t = σ(W_a u_t)          recurrence gate
    i_t = σ(W_x u_t)          input gate
    a_t = exp(-c · softplus(Λ) · r_t)            (c = 8)
    h_t = a_t · h_{t-1} + sqrt(1 − a_t²) · (i_t ⊙ u_t)

The recurrence is evaluated with the chunked associative scan in
``recurrence.linear_scan`` (Pallas TPU version: kernels/rglru_scan.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .recurrence import causal_conv1d, linear_scan

F32 = jnp.float32
_C = 8.0


def init_rglru_params(key, cfg, dtype) -> dict:
    """Gates W_a, W_x are BLOCK-DIAGONAL with one block per head (Griffin
    §2.4) — [nb, dh, dh].  Besides matching the paper, this keeps the whole
    recurrent path elementwise-per-block so TP shards it with zero
    collectives (blocks over the "model" axis)."""
    d, dl, cw = cfg.d_model, cfg.d_lru, cfg.conv_width
    nb = max(cfg.n_heads, 1)
    dh = dl // nb
    assert nb * dh == dl, "lru width must divide into head blocks"
    ks = jax.random.split(key, 6)
    sc = lambda *sh: 1.0 / jnp.sqrt(jnp.float32(sh[0]))
    return {
        "w1": (jax.random.normal(ks[0], (d, dl)) * sc(d)).astype(dtype),
        "w2": (jax.random.normal(ks[1], (d, dl)) * sc(d)).astype(dtype),
        "conv": (jax.random.normal(ks[2], (cw, dl)) * 0.1).astype(dtype),
        "wa": (jax.random.normal(ks[3], (nb, dh, dh)) * sc(dh)).astype(dtype),
        "wx": (jax.random.normal(ks[4], (nb, dh, dh)) * sc(dh)).astype(dtype),
        # Λ init so that a ≈ 0.9..0.999 at r=0.5 (Griffin appendix)
        "lam": jnp.linspace(0.9, 4.0, dl).astype(dtype),
        "w_out": (jax.random.normal(ks[5], (dl, d)) * sc(dl)).astype(dtype),
    }


def rglru_gates(u: jax.Array, p: dict):
    """u [B,S,dl] -> (a, b) of the linear recurrence, both [B,S,dl] f32.

    The block-diagonal gate matmuls run in f32: they are tiny (dl²/nb) and
    the CPU executor lacks a batched bf16×bf16→f32 dot kernel."""
    B, S, dl = u.shape
    nb, dh, _ = p["wa"].shape
    ub = u.reshape(B, S, nb, dh).astype(F32)
    r = jax.nn.sigmoid(jnp.einsum("bshd,hde->bshe", ub,
                                  p["wa"].astype(F32),
                                  preferred_element_type=F32)
                       ).reshape(B, S, dl)
    i = jax.nn.sigmoid(jnp.einsum("bshd,hde->bshe", ub,
                                  p["wx"].astype(F32),
                                  preferred_element_type=F32)
                       ).reshape(B, S, dl)
    log_a = -_C * jax.nn.softplus(p["lam"].astype(F32)) * r
    a = jnp.exp(log_a)
    gated = i * u.astype(F32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return a, b


def rglru_block(x: jax.Array, p: dict, state: Optional[dict] = None,
                chunk: int = 256) -> Tuple[jax.Array, dict]:
    """x [B,S,d] -> (y [B,S,d], new_state).

    ``state`` carries {"h": [B,dl], "conv": [B,cw-1,dl]} across decode steps
    (None ⇒ zeros, training/prefill from scratch).
    """
    B, S, d = x.shape
    dl = p["w1"].shape[1]
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w1"],
                                  preferred_element_type=F32),
                       approximate=True)
    u = jnp.einsum("bsd,de->bse", x, p["w2"],
                   preferred_element_type=F32).astype(x.dtype)
    conv_state = None if state is None else state["conv"]
    u, new_conv = causal_conv1d(u, p["conv"], conv_state)
    a, b = rglru_gates(u, p)
    h0 = (jnp.zeros((B, dl), F32) if state is None
          else state["h"].astype(F32))
    h, h_last = linear_scan(a, b, h0, chunk=chunk)
    y = (gate * h).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"],
                     preferred_element_type=F32).astype(x.dtype)
    return out, {"h": h_last, "conv": new_conv}


def init_rglru_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.d_lru), F32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_lru), dtype),
    }
