"""Mamba-1 selective-SSM block (falcon-mamba-7b, arXiv:2410.05355).

    x ──► in_proj ──► (x_in, z)
    x_in ──► causal conv1d ──► SiLU ──► u
    u ──► x_proj ──► (Δ̂, B, C);  Δ = softplus(dt_proj(Δ̂) + dt_bias)
    h_t = exp(Δ_t ⊗ A) ⊙ h_{t-1} + (Δ_t ⊗ B_t) · u_t      (state N per channel)
    y = (C_t · h_t) + D ⊙ u;   out = out_proj(y ⊙ SiLU(z))

A = −exp(A_log) is the standard negative-real parameterization.  The scan is
the chunked associative scan from ``recurrence.linear_scan`` over [B,S,di,N]
gates (Pallas TPU version: kernels/mamba_scan.py).  falcon-mamba additionally
RMS-norms B, C, Δ (we follow that; it stabilizes bf16).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import rms_norm
from .recurrence import causal_conv1d, linear_scan

F32 = jnp.float32


def init_mamba_params(key, cfg, dtype) -> dict:
    d, di, N, dtr, cw = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dtr,
                         cfg.conv_width)
    ks = jax.random.split(key, 5)
    sc = lambda fan: 1.0 / jnp.sqrt(jnp.float32(fan))
    A_log = jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=F32)[None, :],
                             (di, 1)))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * sc(d)).astype(dtype),
        "conv": (jax.random.normal(ks[1], (cw, di)) * 0.1).astype(dtype),
        "x_proj": (jax.random.normal(ks[2], (di, dtr + 2 * N)) * sc(di)
                   ).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (dtr, di)) * sc(dtr)).astype(dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": A_log.astype(F32),          # kept f32 (sensitive)
        "D": jnp.ones((di,), F32),
        "out_proj": (jax.random.normal(ks[4], (di, d)) * sc(di)).astype(dtype),
    }


def mamba_block(x: jax.Array, p: dict, cfg,
                state: Optional[dict] = None,
                chunk: int = 128) -> Tuple[jax.Array, dict]:
    """x [B,S,d] -> (y [B,S,d], new_state {"h": [B,di,N], "conv": ...})."""
    B, S, d = x.shape
    di, N, dtr = cfg.d_inner, cfg.ssm_state, cfg.dtr
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"],
                    preferred_element_type=F32).astype(x.dtype)
    x_in, z = xz[..., :di], xz[..., di:]
    conv_state = None if state is None else state["conv"]
    u, new_conv = causal_conv1d(x_in, p["conv"], conv_state)
    u = jax.nn.silu(u.astype(F32)).astype(x.dtype)

    dbc = jnp.einsum("bsi,ie->bse", u, p["x_proj"],
                     preferred_element_type=F32)
    dt_in, Bc, Cc = (dbc[..., :dtr], dbc[..., dtr:dtr + N],
                     dbc[..., dtr + N:])
    # falcon-mamba RMS-norms the SSM inputs
    dt_in = rms_norm(dt_in, None)
    Bc = rms_norm(Bc, None)
    Cc = rms_norm(Cc, None)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_in, p["dt_proj"],
                   preferred_element_type=F32) + p["dt_bias"].astype(F32)
    )                                                     # [B,S,di]
    A = -jnp.exp(p["A_log"].astype(F32))                  # [di,N]
    a = jnp.exp(dt[..., None] * A)                        # [B,S,di,N]
    b = (dt[..., None] * Bc[:, :, None, :]) * u.astype(F32)[..., None]
    h0 = (jnp.zeros((B, di, N), F32) if state is None
          else state["h"].astype(F32))
    h, h_last = linear_scan(a, b, h0, chunk=chunk)        # [B,S,di,N]
    y = jnp.einsum("bsin,bsn->bsi", h, Cc) + p["D"].astype(F32) * u.astype(F32)
    y = y * jax.nn.silu(z.astype(F32))
    from .layers import reduce_pet
    out = jnp.einsum("bsi,id->bsd", y.astype(x.dtype), p["out_proj"],
                     preferred_element_type=reduce_pet(cfg)).astype(x.dtype)
    return out, {"h": h_last, "conv": new_conv}


def init_mamba_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), F32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
    }
