"""Linear-recurrence substrate shared by RG-LRU (recurrentgemma) and Mamba-1
(falcon-mamba): a chunked, associative-scan evaluation of

    h_t = a_t ⊙ h_{t-1} + b_t

with elementwise decay ``a``.  The sequence is processed in chunks carried by
``lax.scan`` (bounding live memory to one chunk) and each chunk runs a
parallel ``lax.associative_scan`` — the same two-level schedule the Pallas
kernels implement on TPU (kernels/rglru_scan.py, kernels/mamba_scan.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
                chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """a, b: [B, S, ...] (elementwise); h0: [B, ...].

    Returns (h [B,S,...], h_final [B,...]).
    """
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # ragged tiny shapes: single chunk
    n = S // chunk
    ac = a.reshape((B, n, chunk) + a.shape[2:]).swapaxes(0, 1)
    bc = b.reshape((B, n, chunk) + b.shape[2:]).swapaxes(0, 1)

    def step(h, inp):
        a_i, b_i = inp                                  # [B, chunk, ...]
        aa, bb = lax.associative_scan(_combine, (a_i, b_i), axis=1)
        h_seq = aa * h[:, None] + bb                    # prefix-applied carry
        return h_seq[:, -1], h_seq

    h_last, h_all = lax.scan(step, h0, (ac, bc))
    h_all = h_all.swapaxes(0, 1).reshape((B, S) + a.shape[2:])
    return h_all, h_last


def causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array = None):
    """Depthwise causal conv.  x [B,S,D]; w [cw,D]; state [B,cw-1,D] carries
    the last cw-1 inputs for decode.  Returns (y [B,S,D], new_state)."""
    B, S, D = x.shape
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((B, cw - 1, D), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # [B, S+cw-1, D]
    y = jnp.zeros((B, S, D), F32)
    for i in range(cw):
        y = y + w[i].astype(F32) * xp[:, i : i + S].astype(F32)
    new_state = xp[:, S:]
    return y.astype(x.dtype), new_state
