"""Model configuration for all assigned architectures.

One frozen dataclass describes every family (dense / moe / hybrid / ssm /
audio enc-dec / vlm); family-specific fields default to "off".  Configs for
the ten assigned architectures live in ``repro.configs`` and are plain
instances of this class (full) plus a ``smoke()`` reduction of the same
family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default: d_model // n_heads
    # --- attention ---------------------------------------------------------
    window: int = 0                  # sliding/local attention window (0=full)
    qk_norm: bool = False            # qwen3-style RMSNorm on q/k heads
    qkv_bias: bool = False           # qwen2.5-style bias on q/k/v projections
    nonparametric_ln: bool = False   # olmo-style LN without scale/bias
    rope_theta: float = 10_000.0
    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25    # dispatch capacity = cf * top_k * T / E
    # --- hybrid (recurrentgemma): layer pattern -----------------------------
    # pattern of layer kinds repeated over depth; "attn" uses `window`.
    block_pattern: Tuple[str, ...] = ("attn",)   # e.g. ("rglru","rglru","attn")
    lru_width: Optional[int] = None  # RG-LRU state width (default d_model)
    conv_width: int = 4              # temporal conv width (rglru & mamba)
    # --- SSM (mamba-1) -------------------------------------------------------
    ssm_state: int = 0
    expand: int = 2                  # d_inner = expand * d_model
    dt_rank: Optional[int] = None    # default ceil(d_model / 16)
    # --- encoder-decoder (whisper) -------------------------------------------
    encoder_layers: int = 0          # >0 => enc-dec; encoder is bidirectional
    encoder_seq: int = 1500          # post-conv audio frames (stub frontend)
    # --- vlm ------------------------------------------------------------------
    vision_tokens: int = 0           # prefix of precomputed patch embeddings
    # --- activation / misc ----------------------------------------------------
    act: str = "silu"                # silu (swiglu) | gelu (plain 2-layer MLP)
    norm: str = "rms"                # rms | ln (whisper) | ln_np (olmo)
    # --- perf variants (EXPERIMENTS.md §Perf) ---------------------------------
    head_pad_multiple: int = 0       # pad q heads to a TP-divisible count
    expand_kv: bool = False          # per-q-head KV gather (no GQA reshape)
    bf16_reduce: bool = False        # bf16 outputs on row-parallel matmuls
    seq_parallel: bool = False       # shard residual-stream S over "model":
                                     # AG(bf16)+RS replace the f32 psum pair
    manual_moe: bool = False         # shard_map expert FFN: explicit bf16
                                     # psum on the combine (GSPMD pins f32)
    fused_gu: bool = False           # fuse gate+up projections: ONE bwd dx
                                     # all-reduce instead of two
    remat_save_reduced: bool = False  # remat policy: save the psum-bearing
                                      # layer outputs so the recompute pass
                                      # repeats no fwd all-reduces
    tie_embeddings: bool = False
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "bfloat16"    # stored parameter dtype

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else (
            self.d_model // self.n_heads if self.n_heads else 0
        )

    @property
    def padded_heads(self) -> int:
        """Stored q-head count: n_heads rounded up to head_pad_multiple
        (padded heads are zero-initialized; standard TP head padding).
        For GQA the padding is spread per KV group so grouped attention
        pairing stays exact; padded count must divide by n_kv_heads."""
        if not self.head_pad_multiple or not self.n_heads:
            return self.n_heads
        mult = self.head_pad_multiple
        hp = -(-self.n_heads // mult) * mult
        if self.n_kv_heads and self.n_kv_heads < self.n_heads:
            # per-group padding: group size must be integral
            while hp % self.n_kv_heads:
                hp += mult
        return hp

    @property
    def padded_kv_heads(self) -> int:
        """MHA pads KV alongside q (zero heads attend zero queries); GQA
        keeps real KV heads (padding lives in the q groups)."""
        if (self.head_pad_multiple and self.n_kv_heads
                and self.n_kv_heads == self.n_heads):
            return self.padded_heads
        return self.n_kv_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dtr(self) -> int:
        return self.dt_rank if self.dt_rank is not None else -(-self.d_model // 16)

    @property
    def d_lru(self) -> int:
        return self.lru_width if self.lru_width is not None else self.d_model

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is bounded (window/recurrent) — required for
        the long_500k shape."""
        if self.family == "ssm":
            return True
        if self.window > 0:
            return True
        return False

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Resolved per-layer kind list of length n_layers (pattern tiled,
        truncated)."""
        pat = self.block_pattern
        reps = -(-self.n_layers // len(pat))
        return tuple((pat * reps)[: self.n_layers])

    def n_params(self) -> int:
        """Analytic parameter count (embedding + per-layer weights), used for
        MODEL_FLOPS = 6·N·D roofline terms."""
        d, ff, hd = self.d_model, self.d_ff, self.hd
        H, Hkv = self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d
        total = emb if self.tie_embeddings else 2 * emb
        kinds = self.layer_kinds

        def attn_params() -> int:
            p = d * H * hd + 2 * d * Hkv * hd + H * hd * d
            if self.qkv_bias:
                p += (H + 2 * Hkv) * hd
            if self.qk_norm:
                p += 2 * hd
            return p

        def mlp_params() -> int:
            if self.act == "silu":
                return 3 * d * ff
            return 2 * d * ff

        def moe_params() -> int:
            return self.n_experts * 3 * d * ff + d * self.n_experts

        def rglru_params() -> int:
            dl = self.d_lru
            nb = max(self.n_heads, 1)
            # in/out proj + block-diagonal gates + conv + lambda
            return (2 * d * dl + dl * d + 2 * dl * dl // nb
                    + self.conv_width * dl + dl)

        def mamba_params() -> int:
            di, st, dtr = self.d_inner, self.ssm_state, self.dtr
            return (
                d * 2 * di                   # in_proj (x, z)
                + self.conv_width * di       # conv1d
                + di * (dtr + 2 * st)        # x_proj -> dt, B, C
                + dtr * di                   # dt_proj
                + di * st                    # A_log
                + 2 * di                     # D, dt bias
                + di * d                     # out_proj
            )

        per_kind = {
            "attn": lambda: attn_params() + (
                moe_params() if self.n_experts else mlp_params()
            ),
            "rglru": lambda: rglru_params() + mlp_params(),
            "mamba": lambda: mamba_params(),
        }
        for k in kinds:
            total += per_kind[k]() + 2 * d * (0 if self.nonparametric_ln else 1)
        if self.is_encoder_decoder:
            # encoder self-attn+mlp plus decoder cross-attention
            total += self.encoder_params()
            total += self.n_layers * attn_params()          # cross-attn
        return int(total)

    def encoder_params(self) -> int:
        """Params of the (bidirectional) encoder stack only."""
        if not self.is_encoder_decoder:
            return 0
        d, ff, hd = self.d_model, self.d_ff, self.hd
        H, Hkv = self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * Hkv * hd + H * hd * d
        mlp = (3 if self.act == "silu" else 2) * d * ff
        return int(self.encoder_layers * (attn + mlp))

    def active_params(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        inactive = (self.n_experts - self.top_k) * 3 * d * ff
        return int(self.n_params() - len(self.layer_kinds) * 0 - sum(
            inactive for k in self.layer_kinds if k == "attn"
        ))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and why not if skipped (the skip
    list is documented in DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k dense KV decode is skipped"
    return True, ""
