"""Model assembly for every assigned architecture family.

One functional implementation covers:

* dense decoders (qwen2.5-32b/3b, qwen3-8b, olmo-1b),
* MoE decoders (phi3.5-moe 16e top-2, mixtral 8e top-2 + SWA),
* hybrid RG-LRU/local-attn (recurrentgemma-9b, pattern rglru,rglru,attn),
* attention-free SSM (falcon-mamba-7b),
* encoder-decoder audio (whisper-large-v3; conv frontend stubbed as
  precomputed frame embeddings),
* VLM (internvl2-2b; InternViT stubbed as precomputed patch embeddings
  prefixed to the token sequence).

Layers are *stacked*: parameters carry a leading ``reps`` axis and the depth
loop is ``lax.scan`` over pattern repetitions (pattern-position groups are
scanned together), keeping HLO size O(1) in depth — essential for compiling
64-layer models against 512 placeholder devices.  Remainder layers
(n_layers % len(pattern)) form an unrolled tail.

Entry points:
    init_params(cfg, key)                         -> params
    forward(params, cfg, batch)                   -> logits            (full seq)
    loss_fn(params, cfg, batch)                   -> scalar CE loss
    init_cache(cfg, batch, cache_len)             -> decode cache
    prefill(params, cfg, batch, cache)            -> (logits, cache)
    decode_step(params, cfg, tokens, pos, cache)  -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import ModelConfig
from .mamba import init_mamba_params, init_mamba_state, mamba_block
from .rglru import init_rglru_params, init_rglru_state, rglru_block

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _norm_params(cfg, d, dtype):
    if cfg.norm == "rms":
        return jnp.zeros((d,), dtype)                 # (1 + scale) form
    if cfg.norm == "ln":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return None                                        # ln_np: non-parametric


def _apply_norm(x, p, cfg):
    if cfg.norm == "rms":
        return L.rms_norm(x, p)
    if cfg.norm == "ln":
        return L.layer_norm(x, p["scale"], p["bias"])
    return L.layer_norm(x, None, None)


def _head_mask(cfg) -> Optional[jax.Array]:
    """1 for real q-head slots, 0 for padded (GQA: padding interleaved per
    KV group so grouped pairing stays exact; MHA: padded at the end)."""
    H, Hp, Hkv = cfg.n_heads, cfg.padded_heads, cfg.padded_kv_heads
    if Hp == H:
        return None
    if cfg.n_kv_heads == cfg.n_heads:          # MHA: end padding
        return (jnp.arange(Hp) < H).astype(jnp.float32)
    G = H // cfg.n_kv_heads
    Gp = Hp // cfg.n_kv_heads
    return ((jnp.arange(Hp) % Gp) < G).astype(jnp.float32)


def _init_attn(key, cfg, dtype, cross: bool = False):
    d, H, hd = cfg.d_model, cfg.padded_heads, cfg.hd
    Hkv = cfg.padded_kv_heads
    ks = jax.random.split(key, 4)
    sc_in = 1.0 / jnp.sqrt(jnp.float32(d))
    sc_out = 1.0 / jnp.sqrt(jnp.float32(cfg.n_heads * hd))
    wq = jax.random.normal(ks[0], (d, H, hd)) * sc_in
    wo = jax.random.normal(ks[3], (H, hd, d)) * sc_out
    mask = _head_mask(cfg)
    if mask is not None:  # zero padded heads: exact n_heads semantics
        wq = wq * mask[None, :, None]
        wo = wo * mask[:, None, None]
    wk = jax.random.normal(ks[1], (d, Hkv, hd)) * sc_in
    wv = jax.random.normal(ks[2], (d, Hkv, hd)) * sc_in
    if Hkv > cfg.n_kv_heads:  # MHA KV padding: zero heads
        kv_mask = (jnp.arange(Hkv) < cfg.n_kv_heads).astype(wk.dtype)
        wk = wk * kv_mask[None, :, None]
        wv = wv * kv_mask[None, :, None]
    p = {
        "wq": wq.astype(dtype),
        "wk": wk.astype(dtype),
        "wv": wv.astype(dtype),
        "wo": wo.astype(dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((Hkv, hd), dtype)
        p["bv"] = jnp.zeros((Hkv, hd), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _init_mlp(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    sc_in = 1.0 / jnp.sqrt(jnp.float32(d))
    sc_out = 1.0 / jnp.sqrt(jnp.float32(ff))
    if cfg.act == "silu":
        if cfg.fused_gu:
            return {
                "w_gu": (jax.random.normal(ks[0], (d, 2, ff)) * sc_in
                         ).astype(dtype),
                "w_down": (jax.random.normal(ks[2], (ff, d)) * sc_out
                           ).astype(dtype),
            }
        return {
            "w_gate": (jax.random.normal(ks[0], (d, ff)) * sc_in).astype(dtype),
            "w_up": (jax.random.normal(ks[1], (d, ff)) * sc_in).astype(dtype),
            "w_down": (jax.random.normal(ks[2], (ff, d)) * sc_out).astype(dtype),
        }
    return {
        "w_up": (jax.random.normal(ks[1], (d, ff)) * sc_in).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (ff, d)) * sc_out).astype(dtype),
    }


def _init_moe(key, cfg, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    sc_in = 1.0 / jnp.sqrt(jnp.float32(d))
    sc_out = 1.0 / jnp.sqrt(jnp.float32(ff))
    if cfg.fused_gu:
        return {
            "router": (jax.random.normal(ks[0], (d, E)) * sc_in).astype(F32),
            "w_gu": (jax.random.normal(ks[1], (E, d, 2, ff)) * sc_in
                     ).astype(dtype),
            "w_down": (jax.random.normal(ks[3], (E, ff, d)) * sc_out
                       ).astype(dtype),
        }
    return {
        "router": (jax.random.normal(ks[0], (d, E)) * sc_in).astype(F32),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff)) * sc_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, ff)) * sc_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, ff, d)) * sc_out).astype(dtype),
    }


def _init_layer(key, cfg, kind: str, dtype, cross: bool = False):
    """One decoder layer's params for a given kind."""
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": _norm_params(cfg, d, dtype)}
    if kind == "attn":
        p["attn"] = _init_attn(ks[0], cfg, dtype)
        p["norm2"] = _norm_params(cfg, d, dtype)
        if cfg.n_experts:
            p["moe"] = _init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = _init_mlp(ks[1], cfg, dtype)
    elif kind == "rglru":
        p["rglru"] = init_rglru_params(ks[0], cfg, dtype)
        p["norm2"] = _norm_params(cfg, d, dtype)
        p["mlp"] = _init_mlp(ks[1], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = init_mamba_params(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["cross_attn"] = _init_attn(ks[2], cfg, dtype, cross=True)
        p["norm_cross"] = _norm_params(cfg, d, dtype)
    return p


def _stack_init(init_one, n, key):
    """vmap an init function over n split keys -> stacked leaves [n, ...]."""
    return jax.vmap(init_one)(jax.random.split(key, n))


def _depth_plan(cfg) -> Tuple[int, Tuple[str, ...]]:
    """(reps, tail_kinds): n_layers = reps*len(pattern) + len(tail)."""
    pat = cfg.block_pattern
    reps = cfg.n_layers // len(pat)
    tail = cfg.layer_kinds[reps * len(pat):]
    return reps, tail


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.vocab_size
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (V, d)) * 0.02).astype(dtype),
        "final_norm": _norm_params(cfg, d, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(keys[1], (V, d)) * 0.02
                             ).astype(dtype)
    reps, tail = _depth_plan(cfg)
    pat = cfg.block_pattern
    cross = cfg.is_encoder_decoder
    if reps:
        params["blocks"] = tuple(
            _stack_init(
                lambda k, kind=kind: _init_layer(k, cfg, kind, dtype, cross),
                reps, jax.random.fold_in(keys[2], i))
            for i, kind in enumerate(pat)
        )
    else:
        params["blocks"] = ()
    params["tail"] = tuple(
        _init_layer(jax.random.fold_in(keys[3], i), cfg, kind, dtype, cross)
        for i, kind in enumerate(tail)
    )
    if cfg.is_encoder_decoder:
        enc_cfg = cfg  # same width; encoder is bidirectional full attention
        params["encoder"] = {
            "blocks": _stack_init(
                lambda k: _init_layer(k, enc_cfg, "attn", dtype, cross=False),
                cfg.encoder_layers, keys[4]),
            "final_norm": _norm_params(cfg, d, dtype),
        }
    return params


def param_count(params) -> int:
    return int(sum(x.size for x in jax.tree_util.tree_leaves(params)))


# ---------------------------------------------------------------------------
# Layer application (full-sequence: training / prefill)
# ---------------------------------------------------------------------------

def _attn_full(x, p, cfg, positions, *, causal, window, schedule, enc_out=None):
    h = _apply_norm(x, p["norm1"], cfg)
    q, k, v = L.qkv_project(h, p["attn"], cfg, positions)
    o = L.blocked_attention(q, k, v, causal=causal, window=window,
                            schedule=schedule)
    x = x + jax.ad_checkpoint.checkpoint_name(
        L.out_project(o, p["attn"], cfg), "reduced_out")
    if enc_out is not None:
        h = _apply_norm(x, p["norm_cross"], cfg)
        pc = p["cross_attn"]
        q = jnp.einsum("bsd,dhk->bshk", h, pc["wq"],
                       preferred_element_type=F32).astype(h.dtype)
        k = jnp.einsum("bsd,dhk->bshk", enc_out, pc["wk"],
                       preferred_element_type=F32).astype(h.dtype)
        v = jnp.einsum("bsd,dhk->bshk", enc_out, pc["wv"],
                       preferred_element_type=F32).astype(h.dtype)
        o = L.blocked_attention(q, k, v, causal=False, window=0,
                                schedule="masked")
        x = x + L.out_project(o, pc, cfg)
    h = _apply_norm(x, p["norm2"], cfg)
    if cfg.n_experts:
        mo, _ = L.moe_apply_manual(h, p["moe"], cfg)
        x = x + jax.ad_checkpoint.checkpoint_name(mo, "reduced_out")
    else:
        x = x + jax.ad_checkpoint.checkpoint_name(
            L.mlp(h, p["mlp"], cfg), "reduced_out")
    return x


def _layer_full(x, p, kind, cfg, positions, schedule, enc_out=None):
    if kind == "attn":
        return _attn_full(x, p, cfg, positions, causal=True,
                          window=cfg.window, schedule=schedule,
                          enc_out=enc_out)
    if kind == "rglru":
        h = _apply_norm(x, p["norm1"], cfg)
        o, _ = rglru_block(h, p["rglru"])
        x = x + o
        h = _apply_norm(x, p["norm2"], cfg)
        return x + L.mlp(h, p["mlp"], cfg)
    if kind == "mamba":
        h = _apply_norm(x, p["norm1"], cfg)
        o, _ = mamba_block(h, p["mamba"], cfg)
        return x + o
    raise ValueError(kind)


def _sp(x, cfg):
    """Megatron-style sequence parallelism: between layers the residual
    stream is sharded over "model" on S, so GSPMD materializes an
    all-gather(bf16) before the column-parallel matmuls and a
    reduce-scatter after the row-parallel ones instead of a full f32
    all-reduce pair (≈4× fewer wire bytes per site)."""
    if not cfg.seq_parallel:
        return x
    from jax.sharding import PartitionSpec as P
    return lax.with_sharding_constraint(x, P(None, "model", None))


def _run_depth(x, params, cfg, positions, schedule, enc_out=None,
               remat: bool = False):
    pat = cfg.block_pattern

    def body(carry, block_params):
        y = carry
        for kind, p in zip(pat, block_params):
            y = _layer_full(y, p, kind, cfg, positions, schedule, enc_out)
            y = _sp(y, cfg)
        return y, None

    if remat:
        policy = jax.checkpoint_policies.nothing_saveable
        if cfg.remat_save_reduced:
            policy = jax.checkpoint_policies.save_only_these_names(
                "reduced_out")
        body = jax.checkpoint(body, policy=policy)
    if params["blocks"]:
        x, _ = lax.scan(body, x, params["blocks"])
    reps, tail = _depth_plan(cfg)
    for kind, p in zip(tail, params["tail"]):
        x = _layer_full(x, p, kind, cfg, positions, schedule, enc_out)
    return x


def _encode(params, cfg, frames, schedule="masked", remat=False):
    """Whisper encoder over stub frame embeddings [B, T, d]."""
    B, T, d = frames.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    enc = params["encoder"]

    def body(carry, p):
        return _attn_full(carry, p, cfg, positions, causal=False, window=0,
                          schedule="masked"), None

    if remat:
        policy = jax.checkpoint_policies.nothing_saveable
        if cfg.remat_save_reduced:
            policy = jax.checkpoint_policies.save_only_these_names(
                "reduced_out")
        body = jax.checkpoint(body, policy=policy)
    x, _ = lax.scan(body, frames, enc["blocks"])
    return _apply_norm(x, enc["final_norm"], cfg)


# ---------------------------------------------------------------------------
# Public: forward / loss
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg, batch):
    """Token (+ modality prefix) embedding.  Returns (x, positions,
    text_offset) where text tokens start at text_offset in the sequence."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens]             # gather [B,S,d]
    offset = 0
    if cfg.family == "vlm" and "patches" in batch:
        vis = batch["patches"].astype(x.dtype)      # [B, n_vis, d] (stub)
        x = jnp.concatenate([vis, x], axis=1)
        offset = vis.shape[1]
    S_tot = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S_tot)[None], (B, S_tot))
    return x, positions, offset


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            schedule: str = "masked", remat: bool = False) -> jax.Array:
    """Full-sequence logits [B, S(+prefix), V]."""
    x, positions, _ = _embed_inputs(params, cfg, batch)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["frames"].astype(x.dtype),
                          remat=remat)
    x = _run_depth(x, params, cfg, positions, schedule, enc_out, remat=remat)
    x = _apply_norm(x, params["final_norm"], cfg)
    unembed = params.get("unembed", params["embed"])
    return jnp.einsum("bsd,vd->bsv", x, unembed, preferred_element_type=F32)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            schedule: str = "masked", remat: bool = True) -> jax.Array:
    """Next-token cross-entropy (text positions only for VLM)."""
    logits = forward(params, cfg, batch, schedule=schedule, remat=remat)
    tokens = batch["tokens"]
    if cfg.family == "vlm" and "patches" in batch:
        logits = logits[:, batch["patches"].shape[1]:]
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(F32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(tgt, F32))
    if mask.shape[1] == tokens.shape[1]:
        mask = mask[:, 1:]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def _cache_one(cfg, kind, batch, cache_len, dtype):
    if kind == "attn":
        size = min(cache_len, cfg.window) if cfg.window else cache_len
        hkv = cfg.padded_kv_heads
        return {
            "k": jnp.zeros((batch, size, hkv, cfg.hd), dtype),
            "v": jnp.zeros((batch, size, hkv, cfg.hd), dtype),
            "pos": jnp.full((batch, size), -1, jnp.int32),
        }
    if kind == "rglru":
        # hybrid: rglru layers carry recurrent state only
        return init_rglru_state(cfg, batch, dtype)
    if kind == "mamba":
        return init_mamba_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None) -> Dict[str, Any]:
    """Decode cache pytree: stacked per pattern-position group + tail +
    (enc-dec) cross-attention K/V."""
    dtype = dtype or _dtype(cfg)
    reps, tail = _depth_plan(cfg)

    def stack(kind):
        one = _cache_one(cfg, kind, batch, cache_len, dtype)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), one)

    cache: Dict[str, Any] = {
        "blocks": tuple(stack(kind) for kind in cfg.block_pattern) if reps
        else (),
        "tail": tuple(_cache_one(cfg, kind, batch, cache_len, dtype)
                      for kind in tail),
    }
    if cfg.is_encoder_decoder:
        T = cfg.encoder_seq
        Hkv, hd = cfg.padded_kv_heads, cfg.hd
        z = jnp.zeros((cfg.n_layers, batch, T, Hkv, hd), dtype)
        cache["cross_k"], cache["cross_v"] = z, z
    return cache


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

def _write_kv(cache, k_new, v_new, positions):
    """Write S_new tokens into a (possibly ring) KV cache.
    k_new [B,S,Hkv,hd]; positions [B,S] absolute (per-request), or [1,S]
    shared — the ALIGNED path: one in-place dynamic-update-slice instead of
    a scatter (XLA's scatter expansion materializes the whole cache;
    EXPERIMENTS.md §Perf cell C)."""
    size = cache["k"].shape[1]
    B = k_new.shape[0]
    if positions.shape[0] == 1:  # aligned batch: same slot for every row
        slot = positions[0, 0] % size
        k = lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
        pos_col = jnp.broadcast_to(positions[:1, :1],
                                   (cache["pos"].shape[0], 1)
                                   ).astype(cache["pos"].dtype)
        pos = lax.dynamic_update_slice(cache["pos"], pos_col, (0, slot))
        return {"k": k, "v": v, "pos": pos}
    slots = positions % size
    bidx = jnp.arange(B)[:, None]
    k = cache["k"].at[bidx, slots].set(k_new)
    v = cache["v"].at[bidx, slots].set(v_new)
    pos = cache["pos"].at[bidx, slots].set(positions)
    return {"k": k, "v": v, "pos": pos}


def _attn_decode(x, p, cfg, cache, pos, enc_cross=None, aligned=False):
    """One-token attention layer.  x [B,1,d]; pos [B] (aligned: all equal)."""
    h = _apply_norm(x, p["norm1"], cfg)
    q, k, v = L.qkv_project(h, p["attn"], cfg, pos[:, None])
    cache = _write_kv(cache, k, v,
                      pos[:1, None] if aligned else pos[:, None])
    kvp = cache["pos"]
    if cfg.window:  # fold window masking into the position array
        kvp = jnp.where(kvp > pos[:, None] - cfg.window, kvp, -1)
    o = L.decode_attention(q, cache["k"], cache["v"], pos, kvp)
    x = x + L.out_project(o, p["attn"], cfg)
    if enc_cross is not None:
        ck, cv = enc_cross
        h = _apply_norm(x, p["norm_cross"], cfg)
        pc = p["cross_attn"]
        q = jnp.einsum("bsd,dhk->bshk", h, pc["wq"],
                       preferred_element_type=F32).astype(h.dtype)
        T = ck.shape[1]
        o = L.decode_attention(
            q, ck, cv, jnp.full((x.shape[0],), T, jnp.int32),
            jnp.broadcast_to(jnp.arange(T)[None], ck.shape[:2]))
        x = x + L.out_project(o, pc, cfg)
    h = _apply_norm(x, p["norm2"], cfg)
    if cfg.n_experts:
        mo, _ = L.moe_apply(h, p["moe"], cfg, group_size=h.shape[0],
                            min_capacity=h.shape[0])
        x = x + mo
    else:
        x = x + L.mlp(h, p["mlp"], cfg)
    return x, cache


def _layer_decode(x, p, kind, cfg, cache, pos, enc_cross=None,
                  aligned=False):
    if kind == "attn":
        return _attn_decode(x, p, cfg, cache, pos, enc_cross, aligned)
    if kind == "rglru":
        h = _apply_norm(x, p["norm1"], cfg)
        o, st = rglru_block(h, p["rglru"], state=cache)
        x = x + o
        h = _apply_norm(x, p["norm2"], cfg)
        return x + L.mlp(h, p["mlp"], cfg), st
    if kind == "mamba":
        h = _apply_norm(x, p["norm1"], cfg)
        o, st = mamba_block(h, p["mamba"], cfg, state=cache)
        return x + o, st
    raise ValueError(kind)


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, pos: jax.Array,
                cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decoding step.  tokens [B,1] int32; pos [B] per-request absolute
    positions, or a scalar () for an ALIGNED batch (uniform position: the
    KV write compiles to one in-place DUS instead of a scatter).

    Returns (logits [B,1,V], new cache).
    """
    aligned = (pos.ndim == 0)
    if aligned:
        pos = jnp.broadcast_to(pos[None], (tokens.shape[0],))
        pos = pos.astype(jnp.int32)
    x = params["embed"][tokens]
    pat = cfg.block_pattern
    new_blocks = []
    if params["blocks"]:
        # scan over repetitions with the cache as CARRY: each step reads and
        # writes only its layer slice via aliased dynamic-(update-)slice —
        # scan-ys assembly would copy the full stacked cache every step
        # (EXPERIMENTS.md §Perf cell C)
        def body(carry, inp):
            y, blocks_cache = carry
            block_params, rep_idx = inp
            blocks_cache = list(blocks_cache)
            for pi, kind in enumerate(pat):
                enc_cross = None
                if kind == "attn" and cfg.is_encoder_decoder:
                    layer_idx = rep_idx * len(pat) + pi
                    enc_cross = (cache["cross_k"][layer_idx],
                                 cache["cross_v"][layer_idx])
                c_i = jax.tree_util.tree_map(
                    lambda c: lax.dynamic_index_in_dim(
                        c, rep_idx, 0, keepdims=False), blocks_cache[pi])
                y, c_new = _layer_decode(y, block_params[pi], kind, cfg,
                                         c_i, pos, enc_cross, aligned)
                blocks_cache[pi] = jax.tree_util.tree_map(
                    lambda full, new: lax.dynamic_update_index_in_dim(
                        full, new.astype(full.dtype), rep_idx, 0),
                    blocks_cache[pi], c_new)
            return (y, tuple(blocks_cache)), None

        reps = jax.tree_util.tree_leaves(params["blocks"][0])[0].shape[0]
        (x, new_blocks), _ = lax.scan(
            body, (x, cache["blocks"]),
            (params["blocks"], jnp.arange(reps)))
    reps_n, tail = _depth_plan(cfg)
    new_tail = []
    for i, (kind, p) in enumerate(zip(tail, params["tail"])):
        enc_cross = None
        if kind == "attn" and cfg.is_encoder_decoder:
            layer_idx = reps_n * len(pat) + i
            enc_cross = (cache["cross_k"][layer_idx],
                         cache["cross_v"][layer_idx])
        x, c = _layer_decode(x, p, kind, cfg, cache["tail"][i], pos,
                             enc_cross, aligned)
        new_tail.append(c)
    new_cache = dict(cache)
    new_cache["blocks"] = tuple(new_blocks) if new_blocks else ()
    new_cache["tail"] = tuple(new_tail)
    x = _apply_norm(x, params["final_norm"], cfg)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, unembed,
                        preferred_element_type=F32)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            cache: Dict[str, Any], *, schedule: str = "masked"
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Process a full prompt, filling the decode cache.

    Implemented as full-sequence forward (for logits) plus cache
    construction; attention caches receive the last ``cache_size`` keys.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x, positions, offset = _embed_inputs(params, cfg, batch)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["frames"].astype(x.dtype))
        # precompute cross K/V for all decoder layers
        cks, cvs = [], []
        reps, tail = _depth_plan(cfg)
        def cross_kv(p):
            pc = p["cross_attn"]
            k = jnp.einsum("btd,dhk->bthk", enc_out, pc["wk"],
                           preferred_element_type=F32).astype(x.dtype)
            v = jnp.einsum("btd,dhk->bthk", enc_out, pc["wv"],
                           preferred_element_type=F32).astype(x.dtype)
            return k, v
        for gi, kind in enumerate(cfg.block_pattern):
            stacked = params["blocks"][gi]
            k, v = jax.vmap(cross_kv)(stacked)
            cks.append(k)
            cvs.append(v)
        # interleave pattern groups back into layer order
        ck = jnp.stack(cks, axis=1).reshape((-1,) + cks[0].shape[1:]) \
            if cks else None
        # NOTE: pattern interleave: groups are [reps, ...] per position;
        # stack(axis=1) yields [reps, n_pos, ...] -> reshape to layer order.
        cv = jnp.stack(cvs, axis=1).reshape((-1,) + cvs[0].shape[1:]) \
            if cvs else None
        for p in params["tail"]:
            k, v = cross_kv(p)
            ck = jnp.concatenate([ck, k[None]], 0) if ck is not None else k[None]
            cv = jnp.concatenate([cv, v[None]], 0) if cv is not None else v[None]
        cache = dict(cache)
        cache["cross_k"], cache["cross_v"] = ck, cv

    # Full-sequence pass that also returns per-layer K/V and final states.
    pat = cfg.block_pattern
    pos_grid = positions

    def layer_with_cache(y, p, kind, block_cache):
        if kind == "attn":
            h = _apply_norm(y, p["norm1"], cfg)
            q, k, v = L.qkv_project(h, p["attn"], cfg, pos_grid)
            o = L.blocked_attention(q, k, v, causal=True, window=cfg.window,
                                    schedule=schedule)
            y = y + L.out_project(o, p["attn"], cfg)
            if cfg.is_encoder_decoder:
                # cross-attn folded in forward path for enc-dec prefill
                h = _apply_norm(y, p["norm_cross"], cfg)
                pc = p["cross_attn"]
                qc = jnp.einsum("bsd,dhk->bshk", h, pc["wq"],
                                preferred_element_type=F32).astype(h.dtype)
                kc = jnp.einsum("btd,dhk->bthk", enc_out, pc["wk"],
                                preferred_element_type=F32).astype(h.dtype)
                vc = jnp.einsum("btd,dhk->bthk", enc_out, pc["wv"],
                                preferred_element_type=F32).astype(h.dtype)
                oc = L.blocked_attention(qc, kc, vc, causal=False, window=0)
                y = y + L.out_project(oc, pc, cfg)
            h = _apply_norm(y, p["norm2"], cfg)
            if cfg.n_experts:
                mo, _ = L.moe_apply_manual(h, p["moe"], cfg)
                y = y + mo
            else:
                y = y + L.mlp(h, p["mlp"], cfg)
            size = block_cache["k"].shape[1]
            keep = min(size, k.shape[1])
            new_cache = _write_kv(block_cache, k[:, -keep:], v[:, -keep:],
                                  pos_grid[:, -keep:])
            return y, new_cache
        if kind == "rglru":
            h = _apply_norm(y, p["norm1"], cfg)
            o, st = rglru_block(h, p["rglru"])
            y = y + o
            h = _apply_norm(y, p["norm2"], cfg)
            return y + L.mlp(h, p["mlp"], cfg), st
        if kind == "mamba":
            h = _apply_norm(y, p["norm1"], cfg)
            o, st = mamba_block(h, p["mamba"], cfg)
            return y + o, st
        raise ValueError(kind)

    new_blocks = cache["blocks"]
    if params["blocks"]:
        def body(carry, inp):
            y = carry
            block_params, block_cache = inp
            ncs = []
            for pi, kind in enumerate(pat):
                y, nc = layer_with_cache(y, block_params[pi], kind,
                                         block_cache[pi])
                ncs.append(nc)
            return y, tuple(ncs)

        x, new_blocks = lax.scan(body, x,
                                 (params["blocks"], cache["blocks"]))
    new_tail = []
    reps_n, tail = _depth_plan(cfg)
    for i, (kind, p) in enumerate(zip(tail, params["tail"])):
        x, nc = layer_with_cache(x, p, kind, cache["tail"][i])
        new_tail.append(nc)
    new_cache = dict(cache)
    new_cache["blocks"] = new_blocks
    new_cache["tail"] = tuple(new_tail)
    x = _apply_norm(x, params["final_norm"], cfg)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:], unembed,
                        preferred_element_type=F32)
    return logits, new_cache
