"""Model substrate: configs + pure-JAX implementations of all assigned
architecture families."""
from .config import ModelConfig, ShapeConfig, SHAPES, shape_applicable
from .transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_count,
    prefill,
)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
    "decode_step", "forward", "init_cache", "init_params", "loss_fn",
    "param_count", "prefill",
]
