from .pipeline import SyntheticLM, host_shard_batch
from .streaming import (
    BurstyZipfStream, node_count_trace, task_state_sizes, task_workloads,
)

__all__ = [
    "SyntheticLM", "host_shard_batch",
    "BurstyZipfStream", "node_count_trace", "task_state_sizes",
    "task_workloads",
]
