"""Training data pipeline.

``SyntheticLM`` is an infinite, deterministic, Zipf-distributed token stream
(the offline container has no corpus; determinism makes training runs and
checkpoint-restart tests reproducible).  The pipeline is host-sharded: each
host materializes only its slice of the global batch, and a background
prefetch thread keeps ``prefetch`` batches ready — the standard input-bound
mitigation on real pods.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.1
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2

    def __post_init__(self):
        if self.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.host_batch = self.global_batch // self.n_hosts
        # stationary Zipf over the vocab, renormalized (deterministic)
        probs = 1.0 / np.arange(1, self.vocab_size + 1) ** self.zipf_a
        self._probs = probs / probs.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (restart-safe: re-seeding by
        step means checkpoint-restart replays the identical stream)."""
        rng = np.random.default_rng(
            (self.seed, step, self.host_id))
        toks = rng.choice(
            self.vocab_size, size=(self.host_batch, self.seq_len),
            p=self._probs).astype(np.int32)
        return {"tokens": toks}

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        """Prefetching iterator from ``start_step``."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def host_shard_batch(batch: Dict[str, np.ndarray], n_hosts: int,
                     host_id: int) -> Dict[str, np.ndarray]:
    """Slice a global batch to one host's rows (batch axis 0)."""
    out = {}
    for k, v in batch.items():
        b = v.shape[0]
        per = b // n_hosts
        out[k] = v[host_id * per : (host_id + 1) * per]
    return out
