"""Synthetic bursty data stream (paper §6 workload analogue).

The paper evaluates on a Twitter crawl: tweet rate varies hour-to-hour, word
frequencies are Zipfian, and topical bursts skew individual hash buckets.
That dataset is not redistributable, so benchmarks use this generator, which
reproduces the three properties the migration algorithms are sensitive to:

1. diurnal total-rate variation       -> node-count trace (paper: nodes
                                         proportional to tweets/hour, in [8,16])
2. Zipfian task (hash-bucket) loads   -> skewed w_j
3. transient per-topic bursts         -> sudden w_j spikes forcing rebalances

``task_state_sizes`` models per-task operator-state growth (word counters
within a sliding window): state ∝ distinct-weighted recent volume.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass
class BurstyZipfStream:
    """Per-interval task workload generator."""

    m_tasks: int = 64
    zipf_a: float = 1.1              # word-frequency skew
    diurnal_amp: float = 0.4         # total-rate daily swing (fraction)
    burst_prob: float = 0.15         # p(burst starts) per interval
    burst_mult: float = 6.0          # burst multiplies one task's load
    burst_len: int = 3               # intervals a burst lasts
    base_rate: float = 10_000.0      # items per interval
    seed: int = 0

    def intervals(self, n: int) -> np.ndarray:
        """Return w of shape [n, m_tasks]: per-interval task workloads."""
        rng = np.random.default_rng(self.seed)
        # stationary Zipf shares over tasks (hash buckets aggregate words;
        # shuffle so heavy buckets are not adjacent)
        shares = 1.0 / np.arange(1, self.m_tasks + 1) ** self.zipf_a
        rng.shuffle(shares)
        shares /= shares.sum()
        w = np.zeros((n, self.m_tasks))
        active: list = []            # (task, remaining)
        for t in range(n):
            rate = self.base_rate * (
                1.0 + self.diurnal_amp * np.sin(2 * np.pi * t / 24.0)
            )
            cur = shares.copy()
            if rng.random() < self.burst_prob:
                active.append([int(rng.integers(self.m_tasks)),
                               self.burst_len])
            for b in active:
                cur[b[0]] *= self.burst_mult
                b[1] -= 1
            active = [b for b in active if b[1] > 0]
            cur /= cur.sum()
            w[t] = rng.poisson(rate * cur)
        return w


def task_workloads(m: int, n_intervals: int, seed: int = 0, **kw) -> np.ndarray:
    return BurstyZipfStream(m_tasks=m, seed=seed, **kw).intervals(n_intervals)


def task_state_sizes(w: np.ndarray, window: int = 6,
                     bytes_per_item: float = 48.0) -> np.ndarray:
    """Operator-state size per task per interval: counters within a sliding
    window over the stream (paper's word-count / frequent-pattern states).
    Sub-linear in volume (distinct keys saturate): size ∝ volume^0.8."""
    n, m = w.shape
    s = np.zeros_like(w)
    for t in range(n):
        lo = max(0, t - window + 1)
        vol = w[lo : t + 1].sum(axis=0)
        s[t] = bytes_per_item * np.power(vol, 0.8)
    return s


def node_count_trace(w: np.ndarray, n_min: int = 8, n_max: int = 16
                     ) -> np.ndarray:
    """Paper §6: allocate nodes proportional to per-interval volume,
    normalized into [n_min, n_max]."""
    vol = w.sum(axis=1)
    lo, hi = vol.min(), vol.max()
    if hi <= lo:
        return np.full(len(vol), n_min, dtype=np.int64)
    frac = (vol - lo) / (hi - lo)
    return np.round(n_min + frac * (n_max - n_min)).astype(np.int64)
