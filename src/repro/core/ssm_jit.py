"""JIT backend for the SSM planner (``ssm(..., backend="jit")``).

The numpy reference (Fig. 14, ``ssm._ssm_numpy``) evaluates, at every DP
state x0, *bundled* transitions "n_min−1 greedy fillers + one gaining
interval ending at any x ∈ (x0, m]" — an O(m) successor sweep per state that
cannot be expressed as a fixed-shape jax op.  This module reformulates the
recurrence as a *one-jump step-DP* with single-step transitions only:

    G[p, j, k] = max gain partitioning suffix [p, m) into ≤ k cap-feasible
                 intervals, gaining nodes restricted to positions
                 ≥ node_of(p) + j (the same Lemma 3.3/3.5 canonical state).

Transitions out of (p, j, k) — each consumes exactly one interval:

    T0  terminal     0                     if cnt[p] <= k
    TF  filler       G[q, j', k-1]         any q in (p, nxt[p]]  (zero-gain
                                           interval [p, q), possibly short)
    TG  gain         gain(p→x) + G[x, j', k-1]   for x in (p, nxt[p]]
                                           (gaining interval [p, x))

where gain(p→x) is Lemma 3.5's two-candidate maximum (the node containing
x−1; the best straddling/contained node via a range-max over old interval
sizes), with the interval starting *exactly* at p.

Equivalence with the bundled DP
-------------------------------
Every bundled transition "fillers + interval [lb, x) gaining y" decomposes
exactly: full greedy fillers are TF steps with q = nxt[p]; the truncated
filler [q, lb) is a TF step with q' = lb (feasible: lb <= nxt[q]); the
gaining interval is then a TG step *from* lb — and x <= nxt[lb] holds by
predicate duality (lb_global[x] <= lb ⟺ x <= nxt[lb_global[x]], which is
why the shared canonical ``feasible_tol`` predicate matters for
correctness, not just backend consistency).  The gamma update after a
short filler, gamma' = max(gamma, node_of(q)), preserves the exact
candidate set: any node gaining inside [lb, x) has index >= node_of(lb)
anyway.  Conversely, every step-DP path (including "wasteful" short
fillers the bundled DP never takes) realizes a feasible assignment with
the same gain, so it cannot exceed the bundled optimum: the maxima agree.

Why this shape is fast on CPU
-----------------------------
* Every transition consumes one interval, so layer k of G depends only on
  the finished layer k-1: no sequential loop over p — the DP is a
  ``lax.scan`` of n' full sweeps, each a handful of fused [W, mpad] ops.
* The window is ONE feasible jump, clamped at m (successors past m are
  dominated by the x = m option): W = max_{p<m}(min(nxt[p], m) − p).
* With the interval forced to start at p, every quantity in the gain
  formulas is a function of x alone or of p alone, combined by binary
  selects (e.g. Ss[max(lbs[y1(x)], p)] is a select between two 1-D
  tables).  All [W, mpad] gain/mask matrices are therefore precomputed
  ONCE per call with numpy stride tricks (zero-copy sliding windows) and
  reused by every layer; the per-layer work is just: 3 sliding-window
  unfolds of layer k-1 (built as a scan of ``dynamic_slice`` memcpys — no
  scalar gathers), 2 adds, 3 selects, 3 maxes and 1 reduction.
* The range-max over contained nodes collapses into one lookup in a tiny
  dense (npad+2)x(npad+1) all-intervals max table, indexed by a p-side
  row base plus an x-side column — one small-table gather, once per call.
* No argmax is materialized: reconstruction re-derives each optimal
  transition by exact float64 value-matching against the stored layers
  (the DP value path contains only IEEE adds/maxes of the very arrays the
  decoder reads, so equality is bit-exact; any matching transition is a
  valid optimal continuation).

Shape bucketing: small instances (m <= 2048) round m, W and the layer
count to powers of two so one compilation serves many instances; large
instances round m and W to multiples of 256 and use exactly n'+1 layers
(every extra layer is a full sweep).  Padding tasks have zero weight and
zero state, which provably leaves the optimum unchanged: cnt[p >= m] := 0
so padded suffixes are free, and intervals reaching into the padding are
clamped back to m at decode time with identical gain.

The DP runs in float64 via ``jax.experimental.enable_x64`` (scoped — the
rest of the process stays float32).
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np
from numpy.lib.stride_tricks import sliding_window_view

from .intervals import Assignment, greedy_boundaries, max_feasible_ends
from .ssm import Infeasible, MigrationPlan, NEG, _plan, _Pre


def _pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def _ceil_to(x: int, step: int) -> int:
    return ((x + step - 1) // step) * step


def _allranges_max(fs: np.ndarray) -> np.ndarray:
    """T[a, b1] = max(fs[a:b1]) (NEG when empty), a <= len(fs)+1."""
    n = len(fs)
    T = np.full((n + 2, n + 1), NEG, dtype=np.float64)
    for a in range(n):
        acc = NEG
        for b1 in range(a + 1, n + 1):
            acc = max(acc, fs[b1 - 1])
            T[a, b1] = acc
    return T


@lru_cache(maxsize=64)
def _compiled_dp(mpad: int, W: int, nk: int):
    """Build + jit the layered one-jump DP for one (mpad, W, nk) bucket."""
    import jax
    import jax.numpy as jnp

    LROW = mpad + W + 1

    def dp(G1m, G2m, SEL, FEAS, jp1x, cntm, L0):
        f64 = L0.dtype
        NEGa = jnp.asarray(NEG, f64)
        tail0 = jnp.zeros((LROW - mpad, 2), f64)
        rows = jnp.arange(LROW, dtype=jnp.int32)
        wis = jnp.arange(W, dtype=jnp.int32)

        def layer(L1, k):
            # three sliding-window unfolds of layer k-1: U*[wi, p] is the
            # value at successor x = p+1+wi (plane 0, plane 1, and the
            # cand1 jp1-premerged plane)
            L10, L11 = L1[:, 0], L1[:, 1]
            Lc1 = L1[rows, jp1x]

            def unf(_, wi):
                w1 = wi + 1
                return None, (
                    jax.lax.dynamic_slice(L10, (w1,), (mpad,)),
                    jax.lax.dynamic_slice(L11, (w1,), (mpad,)),
                    jax.lax.dynamic_slice(Lc1, (w1,), (mpad,)),
                )

            _, (U0, U1, Uc) = jax.lax.scan(unf, None, wis)

            cols = []
            for j in (0, 1):
                totF = jnp.where(FEAS, jnp.where(SEL[j], U1, U0), NEGa)
                tot1 = G1m[j] + Uc      # invalid entries hold NEG: stay
                tot2 = G2m[j] + U0      # ~-1e30, never win, never overflow
                M = jnp.maximum(jnp.maximum(totF, tot1), tot2)
                red = jnp.max(M, axis=0)                       # [mpad]
                tval = jnp.where(cntm <= k, jnp.asarray(0.0, f64), NEGa)
                cols.append(jnp.maximum(tval, red))
            Lk = jnp.concatenate([jnp.stack(cols, axis=1), tail0], axis=0)
            return Lk, Lk

        ks = jnp.arange(1, nk, dtype=jnp.int32)
        _, Ls = jax.lax.scan(layer, L0, ks)
        return Ls                                   # [nk-1, LROW, 2]

    return jax.jit(dp)


def _pad_inputs(pre: _Pre):
    """Pad into a shape bucket and precompute the k-independent gain and
    mask matrices (host-side numpy; zero-copy sliding windows).

    Padding tasks (index >= m) have zero weight and zero state: they extend
    the last feasible jump for free, add no gain anywhere, and cnt[p >= m]
    is forced to 0 so reaching the padding means "done" for every k — the
    DP optimum over the padded instance equals the real optimum.
    """
    m, n_real, n_new = pre.m, pre.n_real, pre.n_new
    npad = max(n_real, 1)

    # -- bucketed shapes ----------------------------------------------------
    if m > 2048:
        mpad = _ceil_to(m, 256)
        nk = n_new + 1
    else:
        mpad = _pow2(max(m, 4))
        nk = _pow2(n_new + 1)

    Sw_pad = np.concatenate([pre.Sw, np.full(mpad - m, pre.Sw[-1])])
    Ss_pad = np.concatenate([pre.Ss, np.full(mpad - m, pre.Ss[-1])])
    nxt = max_feasible_ends(Sw_pad, pre.tol, np.arange(mpad + 1))

    # one-jump window, clamped at m (successors past m are dominated by the
    # x = m option; without the clamp, jumps running through the zero-weight
    # padding would inflate W to ~mpad - m)
    par = np.arange(m if m > 0 else 1)
    W1 = int((np.minimum(nxt[par], m) - par).max(initial=1))
    if m > 2048:
        W = min(_ceil_to(max(W1, 1), 256), mpad)
    else:
        W = min(_pow2(max(W1, 2)), mpad)
    LROW = mpad + W + 1

    # min cover counts on the padded axis; the padded suffix is free
    cnt = np.zeros(LROW, dtype=np.int64)
    for a in range(min(m, mpad) - 1, -1, -1):
        cnt[a] = 1 + cnt[nxt[a]]
    cnt = np.minimum(cnt, nk)

    # -- 1-D tables over x in [0, LROW) and p in [0, mpad) ------------------
    NOx = np.full(LROW, n_real, dtype=np.int64)        # node containing x
    NOx[: m + 1] = pre.node_of
    NOx[m:] = n_real
    lbs_e = np.full(npad, mpad, dtype=np.int64)
    ubs_e = np.full(npad, mpad, dtype=np.int64)
    lbs_e[:n_real] = pre.lbs
    ubs_e[:n_real] = pre.ubs
    fs = np.full(npad, NEG, dtype=np.float64)
    fs[:n_real] = pre.full_size
    PM2 = _allranges_max(fs)                           # [(npad+2), (npad+1)]

    Ssx = np.empty(LROW, dtype=np.float64)             # Ss at clamped x
    Ssx[: mpad + 1] = Ss_pad
    Ssx[mpad:] = Ss_pad[-1]
    Y1x = np.empty(LROW, dtype=np.int64)               # node_of[x-1]
    Y1x[1:] = NOx[:-1]
    Y1x[0] = 0
    y1c = np.minimum(Y1x, npad - 1)
    LB1x = lbs_e[y1c]                                  # lbs[node_of[x-1]]
    SS_LB1x = Ssx[np.minimum(LB1x, mpad)]
    jp1x = np.clip(Y1x + 1 - NOx, 0, 1)                # cand1 j' plane
    ZH1x = np.where((NOx < n_real) & (ubs_e[np.minimum(NOx, npad - 1)]
                                      <= np.arange(LROW)),
                    NOx, NOx - 1) + 1                  # contained hi + 1

    parange = np.arange(mpad)
    c0 = NOx[:mpad]                                    # node containing p
    c0c = np.minimum(c0, npad - 1)
    # straddler at p (only candidate z == c0; needs z >= gamma, i.e. j == 0)
    sval = Ssx[np.minimum(ubs_e[c0c], mpad)] - \
        Ssx[np.maximum(np.minimum(lbs_e[c0c], mpad), parange)]
    zlo0 = np.where((c0 < n_real) & (lbs_e[c0c] >= parange), c0, c0 + 1)
    zlo_j = [np.maximum(zlo0, c0 + j) for j in (0, 1)]

    # -- [W, mpad] gain/mask matrices (row wi <-> successor x = p+1+wi) -----
    def unf(T):      # rows wi = T[1+wi : 1+wi+mpad]  (zero-copy view)
        return sliding_window_view(T, mpad)[1 : W + 1]

    wi_col = np.arange(W, dtype=np.int64)[:, None]
    FEAS = wi_col <= (nxt[:mpad] - parange - 1)[None, :]
    Xu = wi_col + parange[None, :] + 1
    Y1u = unf(Y1x)
    g1 = unf(Ssx) - np.where(unf(LB1x) >= parange[None, :],
                             unf(SS_LB1x), Ss_pad[:mpad][None, :])
    G1m, G2m, SEL = [], [], []
    idx_x = unf(ZH1x)
    for j in (0, 1):
        gam = (c0 + j)[None, :]
        v1 = FEAS & (Y1u >= gam) & (Y1u < n_real) & (g1 > 0)
        G1m.append(np.where(v1, g1, NEG))
        # contained-range max: one lookup in the tiny all-ranges table,
        # row base from the p side, column from the x side
        g2 = np.take(PM2.reshape(-1),
                     zlo_j[j][None, :] * (npad + 1) + idx_x)
        if j == 0:
            s_ok = (c0 < n_real)[None, :] & (ubs_e[c0c][None, :] <= Xu)
            g2 = np.maximum(g2, np.where(s_ok, sval[None, :], NEG))
        G2m.append(np.where(FEAS & (g2 > 0), g2, NEG))
        SEL.append(unf(NOx) < gam)                    # filler j' == 1

    # layer 0: zero intervals left — done iff the suffix is already empty
    L0 = np.where((cnt == 0)[:, None], 0.0, NEG).repeat(2, axis=1)

    return dict(mpad=mpad, W=W, nk=nk, LROW=LROW, nxt=nxt, cnt=cnt,
                NOx=NOx, jp1x=jp1x, G1m=G1m, G2m=G2m, SEL=SEL, FEAS=FEAS,
                L0=L0, sval=sval, zlo_j=zlo_j, ZH1x=ZH1x, ubs_e=ubs_e)


def ssm_jit(old: Assignment, w: np.ndarray, s: np.ndarray,
            pre: _Pre) -> MigrationPlan:
    """jit backend entry point; called by ``ssm()`` after the shared
    (backend-independent) feasibility checks have passed."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    m, n_new, n_real, n_total = pre.m, pre.n_new, pre.n_real, pre.n_total
    pad = _pad_inputs(pre)
    mpad, W, nk = pad["mpad"], pad["W"], pad["nk"]
    dp = _compiled_dp(mpad, W, nk)
    i32 = np.int32
    with enable_x64():
        Ls = dp(jnp.asarray(np.stack(pad["G1m"])),
                jnp.asarray(np.stack(pad["G2m"])),
                jnp.asarray(np.stack(pad["SEL"])),
                jnp.asarray(pad["FEAS"]),
                jnp.asarray(pad["jp1x"].astype(i32)),
                jnp.asarray(pad["cnt"][:mpad].astype(i32)),
                jnp.asarray(pad["L0"]))
        Ls = np.asarray(Ls)                     # [nk-1, LROW, 2]

    L = np.concatenate([pad["L0"][None], Ls])   # L[k] = layer k values
    total_gain = float(L[n_new, 0, 0])
    if total_gain <= NEG / 2:
        raise Infeasible("no feasible solution found")

    # --- reconstruction: exact value-matching against stored layers --------
    nxt, cnt, NOx, jp1x = pad["nxt"], pad["cnt"], pad["NOx"], pad["jp1x"]
    G1m, G2m = pad["G1m"], pad["G2m"]
    items, full_size = pre.items, pre.full_size
    nxt_real = np.minimum(nxt[: m + 1], m)
    new_ivs: list = [(m, m)] * n_total
    free_ivs: list = []
    x0, j, k = 0, 0, n_new
    while x0 < m:
        Gv = L[k, x0, j]
        if cnt[x0] <= k and Gv == 0.0:
            # zero-gain completion: greedy split of [x0, m)
            bs = greedy_boundaries(nxt_real, x0, m)
            free_ivs += [(bs[i], bs[i + 1]) for i in range(len(bs) - 1)]
            break
        assert k >= 1, "decode: positive value with no intervals left"
        gamma = int(NOx[x0]) + j
        prev = L[k - 1]
        nwin = min(int(nxt[x0]) - x0, W)
        wis = np.arange(nwin)
        xs = x0 + 1 + wis
        totF = prev[xs, (NOx[xs] < gamma).astype(np.int64)]
        hitF = np.nonzero(totF == Gv)[0]
        if hitF.size:                                  # filler [x0, q)
            q = x0 + 1 + int(hitF[0])
            free_ivs.append((x0, min(q, m)))
            j = 1 if NOx[q] < gamma else 0
            x0, k = q, k - 1
            continue
        tot1 = G1m[j][wis, x0] + prev[xs, jp1x[xs]]
        hit1 = np.nonzero(tot1 == Gv)[0]
        if hit1.size:                                  # gain via cand1
            x = x0 + 1 + int(hit1[0])
            y = int(NOx[x - 1])
        else:                                          # gain via cand2
            tot2 = G2m[j][wis, x0] + prev[xs, 0]
            hit2 = np.nonzero(tot2 == Gv)[0]
            assert hit2.size, "decode: no transition matches the DP value"
            x = x0 + 1 + int(hit2[0])
            g2v = float(G2m[j][x - x0 - 1, x0])
            c0 = int(NOx[x0])
            y = -1
            if (j == 0 and c0 < n_real and int(pad["ubs_e"][c0]) <= x
                    and float(pad["sval"][x0]) == g2v):
                y = c0                                 # straddler at x0
            else:
                zlo = int(pad["zlo_j"][j][x0])
                zhi = int(pad["ZH1x"][x]) - 1
                assert 0 <= zlo <= zhi < n_real, "decode: empty cand2 range"
                sub = full_size[zlo : zhi + 1]
                y = zlo + int(np.argmax(sub))
        node_id = items[y][0]
        new_ivs[node_id] = (x0, min(x, m))
        j = min(max(y + 1 - int(NOx[min(x, len(NOx) - 1)]), 0), 1)
        x0, k = x, k - 1
    used = {i for i, iv in enumerate(new_ivs) if iv[1] > iv[0]}
    free_nodes = [i for i in range(n_total) if i not in used]
    free_ivs = [(lo, hi) for lo, hi in free_ivs if hi > lo]
    assert len(free_ivs) <= len(free_nodes), "more intervals than nodes"
    for node_id, iv in zip(free_nodes, free_ivs):
        new_ivs[node_id] = iv
    new = Assignment(m, tuple(new_ivs))
    plan = _plan(old, new, s)
    assert abs(plan.gain - total_gain) < 1e-6 * max(1.0, abs(total_gain)), (
        plan.gain,
        total_gain,
    )
    return plan
