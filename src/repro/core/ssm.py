"""Optimal single-step migration (paper §3).

Three implementations, strongest assumptions last:

* ``brute_force``     — tiny instances; enumerates every partition (empty
                        intervals allowed) and solves the interval→node
                        assignment exactly with a bitmask DP (full bipartite
                        matching, no structural assumptions).  Oracle #1.
* ``simple_ssm``      — Fig. 12 equivalent: exact DP over
                        (suffix, last-used-node, #intervals) exploiting only
                        the *non-crossing* property of optimal matchings.
                        O(m^2·n·n') time.  Oracle #2 for medium sizes.
* ``ssm``             — Fig. 14: the paper's O(m^2·n') time / O(m·n') space
                        DP using Lemmas 3.2–3.5.  This is the production
                        planner.

Why non-crossing is safe (used by both DPs): if old nodes u < v (disjoint
ordered old intervals) were matched to new intervals B > A (ordered), then
gain(u,B) > 0 needs I_u.ub > B.lo >= A.hi and gain(v,A) > 0 needs
I_v.lo < A.hi <= I_u.ub <= I_v.lo — a contradiction, so at most one of any
crossing pair has positive gain and the matching can be un-crossed for free.

Free-interval placement in reconstruction cannot add gain: if it could, the
resulting assignment would beat ``maxgain``, contradicting DP optimality.
Tests assert the realized assignment's cost equals the DP's predicted cost.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from .intervals import (
    Assignment,
    balance_cap,
    feasible_tol,
    greedy_boundaries,
    measure,
    migration_cost,
    migration_gain,
    min_cover_counts,
    min_feasible_starts,
    next_jump,
    overlap_measure,
    prefix_sum,
    satisfies_balance,
    _EPS,
)

NEG = -1e30


class Infeasible(ValueError):
    """No contiguous partition satisfies the balance cap (some single task
    exceeds (1+tau)W/n', or n' is too small)."""


@dataclass(frozen=True)
class MigrationPlan:
    old: Assignment
    new: Assignment
    gain: float
    cost: float

    @property
    def n_active(self) -> int:
        """Nodes that own at least one task after the migration."""
        return sum(1 for lo, hi in self.new.intervals if hi > lo)


def _plan(old: Assignment, new: Assignment, s: np.ndarray) -> MigrationPlan:
    g = migration_gain(old, new, s)
    c = migration_cost(old, new, s)
    return MigrationPlan(old=old, new=new, gain=g, cost=c)


# ---------------------------------------------------------------------------
# Oracle #1: full brute force (tiny m, n).
# ---------------------------------------------------------------------------

def brute_force(
    old: Assignment, n_new: int, w: np.ndarray, s: np.ndarray, tau: float
) -> MigrationPlan:
    """Exact optimum by enumerating boundary multisets (empty intervals
    allowed) and solving the assignment with a bitmask DP.  O(C(m+k,k)·2^n)."""
    m = old.m
    if m > 20 or max(old.n_nodes, n_new) > 8:
        raise ValueError("brute_force is for tiny instances only")
    Sw, Ss = prefix_sum(w), prefix_sum(s)
    cap = balance_cap(float(Sw[-1]), n_new, tau)
    tol = feasible_tol(cap)
    n_total = max(old.n_nodes, n_new)
    old_p = old.padded(n_total)

    best_gain, best_assign = NEG, None
    # nondecreasing interior boundaries => intervals in order, empties allowed
    for interior in itertools.combinations_with_replacement(
        range(m + 1), n_new - 1
    ):
        bounds = (0,) + interior + (m,)
        ivs = [(bounds[i], bounds[i + 1]) for i in range(n_new)]
        if any(measure(Sw, lo, hi) > tol for lo, hi in ivs):
            continue
        # bitmask DP over nodes: process intervals in order, each interval
        # assigned to exactly one unused node (full bipartite matching).
        # dp maps used-node-mask -> best gain after assigning a prefix.
        dp = {0: 0.0}
        for (lo, hi) in ivs:
            ndp: dict = {}
            for mask, g in dp.items():
                for node in range(n_total):
                    bit = 1 << node
                    if mask & bit:
                        continue
                    ov = overlap_measure(Ss, old_p.intervals[node], (lo, hi))
                    nm = mask | bit
                    val = g + ov
                    if val > ndp.get(nm, NEG):
                        ndp[nm] = val
            dp = ndp
        g = max(dp.values())
        if g > best_gain + 1e-12:
            best_gain = g
            # reconstruct assignment for this partition greedily re-running DP
            best_assign = (bounds, ivs)
    if best_assign is None:
        raise Infeasible("no feasible partition")
    # second pass: recover the matching for the winning partition
    bounds, ivs = best_assign
    dp = {0: (0.0, ())}
    for idx, (lo, hi) in enumerate(ivs):
        ndp: dict = {}
        for mask, (g, hist) in dp.items():
            for node in range(n_total):
                bit = 1 << node
                if mask & bit:
                    continue
                ov = overlap_measure(Ss, old_p.intervals[node], (lo, hi))
                nm = mask | bit
                val = g + ov
                if nm not in ndp or val > ndp[nm][0]:
                    ndp[nm] = (val, hist + (node,))
        dp = ndp
    g, hist = max(dp.values(), key=lambda t: t[0])
    new_ivs = [(m, m)] * n_total
    for iv, node in zip(ivs, hist):
        new_ivs[node] = iv
    return _plan(old, Assignment(m, tuple(new_ivs)), s)


# ---------------------------------------------------------------------------
# Oracle #2: Simple_SSM — exact non-crossing DP, O(m^2 · n · n').
# ---------------------------------------------------------------------------

def simple_ssm(
    old: Assignment, n_new: int, w: np.ndarray, s: np.ndarray, tau: float
) -> MigrationPlan:
    """DP over f[t][y][k] = max gain partitioning suffix [t, m) into k
    cap-feasible intervals where gaining nodes are drawn (in order) from old
    nodes with position >= y.  Transition: first interval [t, b) is either
    zero-gain or matched to some y' >= y."""
    m = old.m
    Sw, Ss = prefix_sum(w), prefix_sum(s)
    cap = balance_cap(float(Sw[-1]), n_new, tau)
    tol = feasible_tol(cap)
    items = old.nonempty()  # sorted by lo
    n_real = len(items)
    lbs = np.array([iv[0] for _, iv in items], dtype=np.int64)
    ubs = np.array([iv[1] for _, iv in items], dtype=np.int64)

    nxt = next_jump(w, cap)
    if (nxt[:-1] <= np.arange(m)).any():
        raise Infeasible("a single task exceeds the balance cap")
    cnt = min_cover_counts(nxt)
    if cnt[0] > n_new:
        raise Infeasible(f"need >= {cnt[0]} intervals, have {n_new}")

    # f[t][y][k]; y in [0, n_real]; t in [0, m]
    f = np.full((m + 1, n_real + 1, n_new + 1), NEG)
    f[m, :, :] = 0.0
    arg = np.full((m + 1, n_real + 1, n_new + 1, 2), -1, dtype=np.int64)
    for t in range(m - 1, -1, -1):
        for k in range(1, n_new + 1):
            for y in range(n_real, -1, -1):
                best, bb, byy = NEG, -1, -1
                # empty interval (consume one of the k without advancing)
                v = f[t, y, k - 1]
                if v > best:
                    best, bb, byy = v, t, -2
                for b in range(t + 1, m + 1):
                    if Sw[b] - Sw[t] > tol:
                        break
                    # zero-gain interval
                    v = f[b, y, k - 1]
                    if v > best:
                        best, bb, byy = v, b, -1
                    # gaining node y' >= y with overlap
                    for yp in range(y, n_real):
                        ov = overlap_measure(
                            Ss, (int(lbs[yp]), int(ubs[yp])), (t, b)
                        )
                        if ov <= 0:
                            continue
                        v = ov + f[b, yp + 1, k - 1]
                        if v > best:
                            best, bb, byy = v, b, yp
                f[t, y, k] = best
                arg[t, y, k] = (bb, byy)

    val = f[0, 0, n_new]
    if val <= NEG / 2:
        raise Infeasible("no feasible solution found")
    # reconstruct
    new_ivs = [(m, m)] * max(old.n_nodes, n_new)
    t, y, k = 0, 0, n_new
    free_ivs = []
    while t < m:
        b, yp = arg[t, y, k]
        b = int(b)
        if yp == -2:  # empty interval
            k = k - 1
        elif yp == -1:
            free_ivs.append((t, b))
            t, k = b, k - 1
        else:
            node_id = items[int(yp)][0]
            new_ivs[node_id] = (t, b)
            t, y, k = b, int(yp) + 1, k - 1
    used = {i for i, iv in enumerate(new_ivs) if iv[1] > iv[0]}
    free_nodes = [i for i in range(len(new_ivs)) if i not in used]
    for node_id, iv in zip(free_nodes, free_ivs):
        new_ivs[node_id] = iv
    return _plan(old, Assignment(m, tuple(new_ivs)), s)


# ---------------------------------------------------------------------------
# SSM — Fig. 14, O(m^2 · n') time, O(m · n') space.
# ---------------------------------------------------------------------------

class _SparseTableMax:
    """Static range-max with argmax in O(1) per query."""

    def __init__(self, vals: np.ndarray):
        n = len(vals)
        self.n = n
        if n == 0:
            return
        K = max(1, int(np.floor(np.log2(n))) + 1)
        self.val = np.full((K, n), NEG)
        self.idx = np.zeros((K, n), dtype=np.int64)
        self.val[0] = vals
        self.idx[0] = np.arange(n)
        j = 1
        while (1 << j) <= n:
            span = 1 << (j - 1)
            a = self.val[j - 1, : n - 2 * span + 1]
            b = self.val[j - 1, span : n - span + 1]
            take_b = b > a
            self.val[j, : n - 2 * span + 1] = np.where(take_b, b, a)
            self.idx[j, : n - 2 * span + 1] = np.where(
                take_b,
                self.idx[j - 1, span : n - span + 1],
                self.idx[j - 1, : n - 2 * span + 1],
            )
            j += 1

    def query(self, lo: int, hi: int) -> Tuple[float, int]:
        """Max over vals[lo:hi]; returns (NEG, -1) when empty."""
        if hi <= lo or self.n == 0:
            return NEG, -1
        j = int(np.floor(np.log2(hi - lo)))
        a = (self.val[j, lo], self.idx[j, lo])
        b = (self.val[j, hi - (1 << j)], self.idx[j, hi - (1 << j)])
        return a if a[0] >= b[0] else b


@dataclass
class _Pre:
    """Backend-independent precomputation shared by the ssm() backends.

    Built once in ``ssm()`` so that *every* backend makes identical
    feasibility decisions (same ``nxt``/``cnt``/``lb_global`` from the same
    canonical predicate) — Infeasible is raised before any backend runs.
    """

    m: int
    n_new: int
    n_real: int
    n_total: int
    Sw: np.ndarray
    Ss: np.ndarray
    cap: float
    tol: float
    items: tuple
    lbs: np.ndarray
    ubs: np.ndarray
    full_size: np.ndarray
    node_of: np.ndarray
    nxt: np.ndarray
    cnt: np.ndarray
    lb_global: np.ndarray


# Below this task count, "auto" stays on the numpy backend: the jit backend
# pays a one-off trace/compile per padded shape bucket, which only amortizes
# on large instances or repeated plans.
_AUTO_JIT_MIN_M = 4096


def ssm(
    old: Assignment, n_new: int, w: np.ndarray, s: np.ndarray, tau: float,
    backend: str = "auto",
) -> MigrationPlan:
    """The paper's SSM (Fig. 14).

    DP state g[x][j][k]: max gain for partitioning suffix tasks [x, m) into
    exactly k cap-feasible intervals (empties allowed) where the available
    gaining nodes are those with position >= gamma'' = node_of(x) + j,
    j ∈ {0, 1} (Lemma 3.3/3.5 canonicalization — see DESIGN.md §1).

    Transition at (x0, j, k): either complete with zero gain (k >= minimum
    cover count of [x0, m)), or choose the first gaining interval to end at
    x ∈ (x0, m]: it is [lb'(x), x) with lb'(x) = max(lb(x), x0) minimal
    feasible (Solve_P1), preceded by n_min-1 greedy zero-gain fillers, and
    matched to one of two candidate nodes (Lemma 3.5):
      cand1: the node containing task x-1;
      cand2: the best node whose old interval does not contain x (realized
             as: the straddler at lb', or the range-max of fully-contained
             old intervals inside [lb', x)).

    ``backend`` selects the DP engine — the plan *value* is identical:

    * ``"numpy"`` — the O(m²·n′) reference above, pure numpy + Python loops.
      Lowest latency for small m; no compile step; easiest to debug.
    * ``"jit"``   — jax.jit'd layered step-DP (``core.ssm_jit``): the
      bundled "n_min−1 fillers + gain" transition is decomposed into
      single-step transitions (terminal / one filler / one gain interval per
      step, each consuming exactly one of the k intervals), which bounds
      every successor to a one-jump window and removes the sequential task
      loop entirely — layer k reads only layer k−1, so the whole DP is a
      ``lax.scan`` of n′ vectorized sweeps over [window × m] gain tables
      precomputed host-side.  Shapes are padded into buckets so repeated
      plans at similar sizes reuse one compilation.  ~70× faster than numpy
      at m = 10⁴ on one CPU core (see BENCH_ssm.json).
    * ``"auto"``  — ``"jit"`` when m ≥ %d, else ``"numpy"``.

    Feasibility (Infeasible) is decided *before* backend dispatch, from the
    canonical predicate in ``intervals.feasible_tol`` — both backends and
    both oracles agree exactly.  Oracle choice for differential work:
    ``brute_force`` is ground truth but only for m ≤ 20 / ≤ 8 nodes;
    ``simple_ssm`` is the readable O(m²·n·n′) reference at moderate m;
    ``benchmarks/ssm_oracles.py`` runs all four on one instance stream.
    """ % _AUTO_JIT_MIN_M
    m = old.m
    if n_new < 1:
        raise ValueError("n_new >= 1 required")
    if backend not in ("auto", "numpy", "jit"):
        raise ValueError(f"unknown ssm backend: {backend!r}")
    w = np.asarray(w, dtype=np.float64)
    s = np.asarray(s, dtype=np.float64)
    Sw, Ss = prefix_sum(w), prefix_sum(s)
    cap = balance_cap(float(Sw[-1]), n_new, tau)
    tol = feasible_tol(cap)
    items = old.nonempty()
    n_real = len(items)
    n_total = max(old.n_nodes, n_new)

    nxt = next_jump(w, cap)
    if m and (nxt[:-1] <= np.arange(m)).any():
        raise Infeasible("a single task exceeds the balance cap")
    cnt = min_cover_counts(nxt)
    if cnt[0] > n_new:
        raise Infeasible(f"need >= {cnt[0]} intervals, have {n_new}")

    if n_real == 0 or m == 0:
        # bootstrap: no old state anywhere — greedy split, zero gain.
        bs = greedy_boundaries(nxt, 0, m)
        ivs = [(bs[i], bs[i + 1]) for i in range(len(bs) - 1)]
        ivs += [(m, m)] * (n_new - len(ivs))
        return _plan(old, Assignment(m, tuple(ivs)).padded(n_total), s)

    lbs = np.array([iv[0] for _, iv in items], dtype=np.int64)
    ubs = np.array([iv[1] for _, iv in items], dtype=np.int64)
    full_size = Ss[ubs] - Ss[lbs]
    # node_of[t] = position (in sorted order) of the old node owning task t
    node_of = np.zeros(m + 1, dtype=np.int64)
    for pos in range(n_real):
        node_of[lbs[pos] : ubs[pos]] = pos
    node_of[m] = n_real  # sentinel: "past the last node"

    # lb_global[x] = minimal lb with weight([lb, x)) <= cap
    lb_global = min_feasible_starts(Sw, tol, np.arange(m + 1))

    pre = _Pre(m=m, n_new=n_new, n_real=n_real, n_total=n_total, Sw=Sw,
               Ss=Ss, cap=cap, tol=tol, items=items, lbs=lbs, ubs=ubs,
               full_size=full_size, node_of=node_of, nxt=nxt, cnt=cnt,
               lb_global=lb_global)
    if backend == "auto":
        backend = "jit" if m >= _AUTO_JIT_MIN_M else "numpy"
    if backend == "jit":
        from . import ssm_jit
        return ssm_jit.ssm_jit(old, w, s, pre)
    return _ssm_numpy(old, w, s, pre)


def _ssm_numpy(old: Assignment, w: np.ndarray, s: np.ndarray,
               pre: _Pre) -> MigrationPlan:
    """Reference backend: the Fig. 14 DP exactly as documented in ssm()."""
    m, n_new, n_real, n_total = pre.m, pre.n_new, pre.n_real, pre.n_total
    Ss, items = pre.Ss, pre.items
    lbs, ubs, node_of = pre.lbs, pre.ubs, pre.node_of
    nxt, cnt, lb_global = pre.nxt, pre.cnt, pre.lb_global
    rmq = _SparseTableMax(pre.full_size)

    # g[x][j][k] and argmax records
    g = np.full((m + 1, 2, n_new + 1), NEG)
    g[m, :, :] = 0.0
    # arg: x (end of gaining interval), cand node position, n_min
    arg_x = np.full((m + 1, 2, n_new + 1), -1, dtype=np.int64)
    arg_y = np.full((m + 1, 2, n_new + 1), -1, dtype=np.int64)
    arg_nm = np.full((m + 1, 2, n_new + 1), -1, dtype=np.int64)

    ks = np.arange(n_new + 1)

    for x0 in range(m - 1, -1, -1):
        c0 = int(node_of[x0])
        # --- per-x0 sweep arrays over x in (x0, m] --------------------------
        xs = np.arange(x0 + 1, m + 1)
        nx = len(xs)
        lbp = np.maximum(lb_global[xs], x0)  # gaining interval is [lbp, x)
        # n_min(x0, x) = 1 + greedy cover count of [x0, lbp(x))
        n_min = np.ones(nx, dtype=np.int64)
        # walk the greedy chain from x0 once; lbp is nondecreasing
        chain_pos, chain_cnt = x0, 0
        for i in range(nx):
            t = int(lbp[i])
            while chain_pos < t:
                chain_pos = int(nxt[chain_pos])
                chain_cnt += 1
            # chain_cnt jumps cover [x0, chain_pos) ⊇ [x0, t); greedy count
            # of [x0, t) is chain_cnt (last jump may be truncated to t).
            n_min[i] = 1 + chain_cnt
        # candidate gains + successor j' per x, per j in {0, 1}
        for j in (0, 1):
            gamma = c0 + j
            if gamma > n_real:
                continue
            cand_gain = np.full((2, nx), NEG)
            cand_y = np.full((2, nx), -1, dtype=np.int64)
            cand_jp = np.zeros((2, nx), dtype=np.int64)
            for i in range(nx):
                x = int(xs[i])
                lb = int(lbp[i])
                # cand1: y1 = node containing task x-1
                y1 = int(node_of[x - 1])
                if y1 >= gamma:
                    gv = Ss[x] - Ss[max(int(lbs[y1]), lb)]
                    if gv > 0:
                        cand_gain[0, i] = gv
                        cand_y[0, i] = y1
                        cx = int(node_of[x]) if x < m else n_real
                        cand_jp[0, i] = min(max(y1 + 1 - cx, 0), 1)
                # cand2: best node z >= gamma with ub_z <= x
                # straddler: node containing lb (if truncated by lb)
                zs = int(node_of[lb]) if lb < m else n_real
                best_g, best_z = NEG, -1
                if zs < n_real and zs >= gamma and int(ubs[zs]) <= x:
                    gv = Ss[int(ubs[zs])] - Ss[max(int(lbs[zs]), lb)]
                    if gv > best_g:
                        best_g, best_z = gv, zs
                # fully-contained: z with lb_z >= lb and ub_z <= x
                zlo = zs if (zs < n_real and int(lbs[zs]) >= lb) else zs + 1
                zlo = max(zlo, gamma)
                # zhi: last node with ub <= x
                cx = int(node_of[x]) if x < m else n_real
                zhi = cx if (cx < n_real and int(ubs[cx]) <= x) else cx - 1
                if zhi >= zlo:
                    gv, zidx = rmq.query(zlo, zhi + 1)
                    if gv > best_g:
                        best_g, best_z = gv, zidx
                if best_z >= 0 and best_g > 0:
                    cand_gain[1, i] = best_g
                    cand_y[1, i] = best_z
                    cand_jp[1, i] = 0  # z+1 <= node_of(x) always
            # --- fold into DP for all k (vectorized over x) ----------------
            for k in range(1, n_new + 1):
                best = 0.0 if cnt[x0] <= k else NEG
                bx, by, bnm = -1, -1, -1
                kk = k - n_min  # remaining intervals after P1
                valid = kk >= 0
                if valid.any():
                    for ci in (0, 1):
                        gains = cand_gain[ci]
                        tgt = np.where(
                            valid,
                            g[xs, cand_jp[ci], np.maximum(kk, 0)],
                            NEG,
                        )
                        tot = np.where(valid, gains + tgt, NEG)
                        bi = int(np.argmax(tot))
                        if tot[bi] > best:
                            best = float(tot[bi])
                            bx, by, bnm = int(xs[bi]), int(cand_y[ci][bi]), int(
                                n_min[bi]
                            )
                g[x0, j, k] = best
                arg_x[x0, j, k] = bx
                arg_y[x0, j, k] = by
                arg_nm[x0, j, k] = bnm

    total_gain = float(g[0, 0, n_new])
    if total_gain <= NEG / 2:
        raise Infeasible("no feasible solution found")

    # --- reconstruction ----------------------------------------------------
    new_ivs: list = [(m, m)] * n_total
    free_ivs: list = []
    x0, j, k = 0, 0, n_new
    while x0 < m:
        bx = int(arg_x[x0, j, k])
        if bx < 0:
            # zero-gain completion: greedy split [x0, m)
            bs = greedy_boundaries(nxt, x0, m)
            free_ivs += [(bs[i], bs[i + 1]) for i in range(len(bs) - 1)]
            break
        y = int(arg_y[x0, j, k])
        nm = int(arg_nm[x0, j, k])
        lb = max(int(lb_global[bx]), x0)
        if lb > x0:
            bs = greedy_boundaries(nxt, x0, lb)
            fill = [(bs[i], bs[i + 1]) for i in range(len(bs) - 1)]
            assert len(fill) == nm - 1, (fill, nm)
            free_ivs += fill
        node_id = items[y][0]
        new_ivs[node_id] = (lb, bx)
        cx = int(node_of[bx]) if bx < m else n_real
        j = min(max(y + 1 - cx, 0), 1)
        x0, k = bx, k - nm
    used = {i for i, iv in enumerate(new_ivs) if iv[1] > iv[0]}
    free_nodes = [i for i in range(n_total) if i not in used]
    for node_id, iv in zip(free_nodes, free_ivs):
        new_ivs[node_id] = iv
    assert len(free_ivs) <= len(free_nodes), "more intervals than nodes"
    new = Assignment(m, tuple(new_ivs))
    plan = _plan(old, new, s)
    # The realized gain must equal the DP's prediction (sanity invariant).
    assert abs(plan.gain - total_gain) < 1e-6 * max(1.0, abs(total_gain)), (
        plan.gain,
        total_gain,
    )
    return plan
