"""The paper's contribution: optimal operator-state migration.

Public API:
    Assignment, migration_cost, migration_gain        (paper §2)
    ssm, simple_ssm, brute_force, MigrationPlan       (paper §3)
    oms, greedy_sequence                              (paper §4.1)
    MTM, PartitionTable, pmc, mtm_aware_plan          (paper §4.2)
    adhoc, greedy_trim, consistent_hashing            (baselines)
    ElasticPlanner, TauSchedule                       (facade)
"""
from .intervals import (
    Assignment,
    balance_cap,
    feasible_tol,
    migration_cost,
    migration_gain,
    moved_tasks,
    prefix_sum,
    satisfies_balance,
)
from .ssm import Infeasible, MigrationPlan, brute_force, simple_ssm, ssm
from .oms import SequenceResult, greedy_sequence, oms
from .mtm import MTM, PMCResult, PartitionTable, mtm_aware_plan, pairwise_gain_matrix, pmc
from .baselines import CHashResult, adhoc, consistent_hashing, greedy_trim
from .planner import ElasticPlanner, TauSchedule

__all__ = [
    "Assignment", "balance_cap", "feasible_tol", "migration_cost",
    "migration_gain", "moved_tasks", "prefix_sum", "satisfies_balance",
    "Infeasible", "MigrationPlan", "brute_force", "simple_ssm", "ssm",
    "SequenceResult", "greedy_sequence", "oms",
    "MTM", "PMCResult", "PartitionTable", "mtm_aware_plan",
    "pairwise_gain_matrix", "pmc",
    "CHashResult", "adhoc", "consistent_hashing", "greedy_trim",
    "ElasticPlanner", "TauSchedule",
]
