"""Baseline migration strategies the paper compares against (§6).

* ``adhoc``              — the Storm-default-scheduler analogue: split tasks
                           into n' contiguous chunks of (near-)equal *task
                           count*, assigned to nodes in id order.  Ignores
                           state sizes and workloads entirely.
* ``greedy_trim``        — a straightforward solution: keep old boundaries
                           where feasible, push boundaries minimally left-to-
                           right to satisfy the cap.  Cheap, but can cascade
                           moves across all nodes.
* ``consistent_hashing`` — classical ring placement ([19] in the paper).
                           Task->node mapping is NOT contiguous, so it breaks
                           the interval routing-table design and gives no
                           balance guarantee; included to quantify exactly
                           that trade-off (cost vs. balance violation).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from .intervals import (
    Assignment,
    balance_cap,
    feasible_tol,
    max_feasible_ends,
    min_feasible_starts,
    prefix_sum,
)
from .ssm import Infeasible, MigrationPlan, _plan


def adhoc(
    old: Assignment, n_new: int, w: np.ndarray, s: np.ndarray, tau: float
) -> MigrationPlan:
    """Equal-task-count contiguous chunks, node i <- chunk i (no matching)."""
    m = old.m
    edges = np.linspace(0, m, n_new + 1).round().astype(np.int64)
    n_total = max(old.n_nodes, n_new)
    ivs = [(int(edges[i]), int(edges[i + 1])) for i in range(n_new)]
    ivs += [(m, m)] * (n_total - n_new)
    return _plan(old, Assignment(m, tuple(ivs)), s)


def greedy_trim(
    old: Assignment, n_new: int, w: np.ndarray, s: np.ndarray, tau: float
) -> MigrationPlan:
    """Left-to-right water-filling: keep each old boundary if the interval it
    closes fits under the cap, else trim; leftover tasks spill rightwards."""
    m = old.m
    w = np.asarray(w, dtype=np.float64)
    Sw = prefix_sum(w)
    cap = balance_cap(float(Sw[-1]), n_new, tau)
    tol = feasible_tol(cap)
    old_items = old.nonempty()
    old_bounds = [iv[1] for _, iv in old_items][: n_new - 1]
    bounds = [0]
    for i in range(n_new - 1):
        lo = bounds[-1]
        # largest feasible hi (canonical predicate — matches ssm/next_jump)
        hi_max = int(max_feasible_ends(Sw, tol, np.array([lo]))[0])
        want = old_bounds[i] if i < len(old_bounds) else hi_max
        hi = min(max(want, lo), hi_max, m)
        bounds.append(hi)
    bounds.append(m)
    if Sw[m] - Sw[bounds[-2]] > tol:
        # tail overloaded: fall back to right-to-left repair
        for i in range(n_new - 1, 0, -1):
            hi = bounds[i + 1]
            lo_min = int(min_feasible_starts(Sw, tol, np.array([hi]))[0])
            if bounds[i] < lo_min:
                bounds[i] = lo_min
        if any(Sw[bounds[i + 1]] - Sw[bounds[i]] > tol for i in range(n_new)):
            raise Infeasible("greedy_trim could not satisfy the cap")
    n_total = max(old.n_nodes, n_new)
    ivs: list = [(m, m)] * n_total
    # assign interval i to the old node whose interval contained its lo
    owner = old.owner_of()
    taken = set()
    free = []
    for i in range(n_new):
        lo, hi = bounds[i], bounds[i + 1]
        if hi <= lo:
            continue
        cand = int(owner[lo]) if lo < m else -1
        if cand >= 0 and cand not in taken:
            ivs[cand] = (lo, hi)
            taken.add(cand)
        else:
            free.append((lo, hi))
    free_nodes = [i for i in range(n_total) if i not in taken]
    for node, iv in zip(free_nodes, free):
        ivs[node] = iv
    return _plan(old, Assignment(m, tuple(ivs)), s)


# ---------------------------------------------------------------------------
# Consistent hashing (non-contiguous ownership; benchmark-only)
# ---------------------------------------------------------------------------

def _hash01(key: str) -> float:
    h = hashlib.md5(key.encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


@dataclass(frozen=True)
class CHashResult:
    owner_old: np.ndarray
    owner_new: np.ndarray
    cost: float                  # state bytes moved
    max_load_ratio: float        # max_i W_i / (W/n')  (balance violation)


def consistent_hashing(
    m: int, n_old: int, n_new: int, w: np.ndarray, s: np.ndarray,
    vnodes: int = 64, seed: int = 0,
) -> CHashResult:
    """Ring placement with ``vnodes`` virtual points per node.  Node ids are
    stable, so growing/shrinking moves only arcs adjacent to the change."""
    task_pos = np.array([_hash01(f"t{seed}:{j}") for j in range(m)])

    def owners(n: int) -> np.ndarray:
        pts, ids = [], []
        for i in range(n):
            for v in range(vnodes):
                pts.append(_hash01(f"n{seed}:{i}:{v}"))
                ids.append(i)
        order = np.argsort(pts)
        pts = np.asarray(pts)[order]
        ids = np.asarray(ids)[order]
        k = np.searchsorted(pts, task_pos, side="left") % len(pts)
        return ids[k]

    o_old, o_new = owners(n_old), owners(n_new)
    s = np.asarray(s, dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    cost = float(s[o_old != o_new].sum())
    loads = np.zeros(n_new)
    np.add.at(loads, o_new, w)
    ideal = w.sum() / n_new
    return CHashResult(o_old, o_new, cost, float(loads.max() / ideal))
