"""Optimal migration sequence — OMS (paper §4.1, Fig. 15).

The paper's OMS enumerates, for the first migration, every balanced
partitioning of the m tasks into n_1 intervals, realizes the best matching
against the current assignment, and recurses on the remaining p-1 migrations.
That recursion re-solves identical sub-problems (the sub-problem depends only
on the *partition* reached, by Lemma 4.1 — node permutations do not change
any subsequent cost).  We therefore implement the same optimum as a layered
shortest-path DP over partitions:

    layer 0:            the current (concrete) assignment
    layer i (1..p):     all τ_i-balanced partitions into n_i intervals
    edge cost(A → B):   total_state − non-crossing max-matching gain(A, B)

which visits each (partition, layer) pair once.  ``oms_cost_lower_bound``
exposes the exact optimum; ``oms`` additionally realizes the concrete
assignment sequence (intervals pinned to node ids) via maximum-gain matching,
step by step — Lemma 4.1 guarantees the realized sequence achieves the DP
cost.  Both are exponential in m via the partition count, like the paper;
they are oracles / PMC building blocks, not the online planner.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .intervals import (
    Assignment,
    enumerate_balanced_partitions,
    match_gain,
    measure,
    migration_cost,
    prefix_sum,
    realize_partition,
)
from .ssm import Infeasible, MigrationPlan, _plan


def partition_items(bounds: Sequence[int]) -> Tuple[Tuple[int, Tuple[int, int]], ...]:
    """View a boundary tuple as ordered (pos, interval) items for matching."""
    return tuple(
        (i, (int(bounds[i]), int(bounds[i + 1]))) for i in range(len(bounds) - 1)
    )


def partition_gain(
    a_bounds: Sequence[int], b_bounds: Sequence[int], Ss: np.ndarray
) -> float:
    """Max non-crossing matching gain between two full partitions of [0, m)."""
    g, _ = match_gain(partition_items(a_bounds), list(b_bounds), Ss)
    return g


@dataclass(frozen=True)
class SequenceResult:
    plans: Tuple[MigrationPlan, ...]
    total_cost: float


def enumerate_layers(
    w: np.ndarray,
    targets: Sequence[Tuple[int, float]],
    limit_per_layer: Optional[int] = None,
) -> List[List[Tuple[int, ...]]]:
    """Balanced partitions for each (n_i, tau_i) migration target."""
    layers: List[List[Tuple[int, ...]]] = []
    for n_i, tau_i in targets:
        parts = list(
            enumerate_balanced_partitions(w, n_i, tau_i, limit=limit_per_layer)
        )
        if not parts:
            raise Infeasible(
                f"no balanced partition for n'={n_i}, tau={tau_i}"
            )
        layers.append(parts)
    return layers


def oms(
    old: Assignment,
    targets: Sequence[Tuple[int, float]],
    w: np.ndarray,
    s: np.ndarray,
    limit_per_layer: Optional[int] = None,
) -> SequenceResult:
    """Exact optimal migration sequence (Definition 2.4).

    ``targets`` is the sequence of (n_i, tau_i).  Returns the realized plans
    whose summed cost equals the layered-DP optimum.
    """
    if not targets:
        return SequenceResult(plans=(), total_cost=0.0)
    w = np.asarray(w, dtype=np.float64)
    s = np.asarray(s, dtype=np.float64)
    Ss = prefix_sum(s)
    total_state = measure(Ss, 0, old.m)
    layers = enumerate_layers(w, targets, limit_per_layer)

    # forward DP: best[i][j] = min cost of reaching partition j of layer i
    old_items = old.nonempty()
    first = layers[0]
    best = np.array(
        [total_state - match_gain(old_items, list(b), Ss)[0] for b in first]
    )
    back: List[np.ndarray] = [np.full(len(first), -1, dtype=np.int64)]
    for li in range(1, len(layers)):
        cur = layers[li]
        prev = layers[li - 1]
        nb = np.full(len(cur), np.inf)
        bk = np.full(len(cur), -1, dtype=np.int64)
        for jc, bc in enumerate(cur):
            for jp, bp in enumerate(prev):
                c = best[jp] + total_state - partition_gain(bp, bc, Ss)
                if c < nb[jc]:
                    nb[jc], bk[jc] = c, jp
        best, _ = nb, back.append(bk)
    # backtrack partition path
    j = int(np.argmin(best))
    total = float(best[j])
    path = [j]
    for li in range(len(layers) - 1, 0, -1):
        j = int(back[li][j])
        path.append(j)
    path.reverse()

    # realize assignments along the path
    plans: List[MigrationPlan] = []
    cur_assign = old
    for li, j in enumerate(path):
        bounds = layers[li][j]
        n_i = targets[li][0]
        new_assign = realize_partition(cur_assign, list(bounds), s, n_i)
        plans.append(_plan(cur_assign, new_assign, s))
        cur_assign = new_assign
    realized = sum(p.cost for p in plans)
    assert abs(realized - total) < 1e-6 * max(1.0, abs(total)), (realized, total)
    return SequenceResult(plans=tuple(plans), total_cost=realized)


def greedy_sequence(
    old: Assignment,
    targets: Sequence[Tuple[int, float]],
    w: np.ndarray,
    s: np.ndarray,
    planner=None,
) -> SequenceResult:
    """Apply optimal *single-step* migration at each step (the paper's
    baseline for Table 1): per-step optimal, sequence-suboptimal."""
    from .ssm import ssm as ssm_solver

    solver = planner or ssm_solver
    plans: List[MigrationPlan] = []
    cur = old
    for n_i, tau_i in targets:
        p = solver(cur, n_i, w, s, tau_i)
        plans.append(p)
        cur = p.new
    return SequenceResult(plans=tuple(plans), total_cost=sum(p.cost for p in plans))
