"""MTM-aware migration (paper §2.2, §4.2): migration transition matrix,
PMC value iteration (Fig. 16), and the runtime MTM-aware planner.

The MDP: states are balanced task *partitions* (Lemma 4.2 — node permutations
never change future costs, so partitions suffice).  From a partition P with
k(P) intervals the environment draws the next node count n' from the MTM row
of k(P); the controller then picks the cheapest next partition.  The
projected cost (Def. 2.7/2.8) is the fixed point of

    C[P] = sum_{n'} MTM[k(P), n']  ·  min_{P' in Parts(n')}
                ( cost(P -> P') + gamma · C[P'] )

which is a gamma-contraction, so value iteration converges geometrically.
The paper's Fig. 16 writes the expectation over next *partitions*; with the
controller free to choose P' given n' (Def. 2.8 "find a migration strategy"),
the inner min over Parts(n') is the faithful Bellman form, and reduces to the
paper's wording when each row has a single reachable partition.

Cost between two full partitions of [0, m) is total_state − the max gain of a
non-crossing interval matching, computed *batched* over all partition pairs
(numpy here; ``repro.kernels.interval_gain`` provides the Pallas/TPU version
of the same batched DP, validated against ``pairwise_gain_matrix``).

Beyond the paper: ``boundary_grid`` coarsens the partition space by snapping
boundaries to multiples of g, which cuts PMC precompute from "hundreds of
minutes on a Spark cluster" (paper Fig. 6) to seconds at equal m — at a small,
measured optimality loss (see benchmarks/fig6_pmc_time.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .intervals import (
    Assignment,
    balance_cap,
    feasible_tol,
    match_gain,
    measure,
    prefix_sum,
    realize_partition,
    _EPS,
)
from .ssm import Infeasible, MigrationPlan, _plan


# ---------------------------------------------------------------------------
# Migration transition matrix
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MTM:
    """Row-stochastic matrix over node counts [n_min, n_max]."""

    n_min: int
    n_max: int
    probs: np.ndarray  # [n_max-n_min+1, n_max-n_min+1]

    def __post_init__(self):
        p = self.probs
        if p.shape != (self.size, self.size):
            raise ValueError("MTM shape mismatch")
        if (p < -1e-12).any():
            raise ValueError("negative probability")
        rs = p.sum(axis=1)
        if not np.allclose(rs, 1.0, atol=1e-6):
            raise ValueError(f"rows must sum to 1, got {rs}")

    @property
    def size(self) -> int:
        return self.n_max - self.n_min + 1

    def row(self, n: int) -> np.ndarray:
        return self.probs[n - self.n_min]

    @staticmethod
    def estimate(history: Sequence[int], n_min: int, n_max: int,
                 smoothing: float = 1e-3) -> "MTM":
        """Count n->n' transitions in a node-count history (paper §2.2:
        "computed using statistics of past server logs").  Laplace smoothing
        keeps unseen transitions reachable."""
        size = n_max - n_min + 1
        counts = np.full((size, size), smoothing, dtype=np.float64)
        for a, b in zip(history[:-1], history[1:]):
            if a == b:
                continue  # no migration between equal counts (paper §6)
            if n_min <= a <= n_max and n_min <= b <= n_max:
                counts[a - n_min, b - n_min] += 1.0
        probs = counts / counts.sum(axis=1, keepdims=True)
        return MTM(n_min=n_min, n_max=n_max, probs=probs)

    @staticmethod
    def uniform(n_min: int, n_max: int) -> "MTM":
        size = n_max - n_min + 1
        return MTM(n_min, n_max, np.full((size, size), 1.0 / size))


# ---------------------------------------------------------------------------
# Partition tables
# ---------------------------------------------------------------------------

def grid_partitions(
    w: np.ndarray, k: int, tau: float, grid: int = 1,
    limit: Optional[int] = None,
) -> List[Tuple[int, ...]]:
    """Balanced partitions of [0, m) into k intervals whose interior
    boundaries are multiples of ``grid`` (grid=1 reproduces the full space)."""
    m = len(w)
    Sw = prefix_sum(w)
    tol = feasible_tol(balance_cap(float(Sw[-1]), k, tau))
    pts = [b for b in range(grid, m, grid)] + [m]
    out: List[Tuple[int, ...]] = []

    def rec(start: int, left: int, acc: Tuple[int, ...]):
        if limit is not None and len(out) >= limit:
            return
        if left == 1:
            if Sw[m] - Sw[start] <= tol:
                out.append(acc + (m,))
            return
        for b in pts:
            if b <= start:
                continue
            if b > m - (left - 1):
                break
            if Sw[b] - Sw[start] > tol:
                break
            rec(b, left - 1, acc + (b,))

    rec(0, k, (0,))
    return out


@dataclass
class PartitionTable:
    """Candidate partitions into UP TO n_max intervals (paper §4.2: "every
    partitioning of the m tasks into up to n_max task intervals") padded to
    a common interval count K (empty tail intervals at m).

    A row with j nonempty intervals is feasible on a k-node cluster (k ≥ j)
    iff its max interval load fits the k-cap (1+τ)W/k — the k−j spare nodes
    idle, exactly like SSM's free nodes."""

    m: int
    n_counts: List[int]                 # nonempty interval count per row
    bounds: np.ndarray                  # [Q, K+1] int64, padded with m
    by_k: Dict[int, np.ndarray]         # legacy exact-count row indices
    max_load: np.ndarray = None         # [Q] max interval load (build w)
    total_w: float = 0.0
    tau: float = 0.0
    n_min: int = 0
    n_max: int = 0

    def feasible_rows(self, k: int) -> np.ndarray:
        """Rows usable as the target of a migration onto k nodes."""
        cap = feasible_tol(balance_cap(self.total_w, k, self.tau))
        counts = np.asarray(self.n_counts)
        return np.nonzero((counts <= k) & (self.max_load <= cap))[0]

    @staticmethod
    def build(
        w: np.ndarray, n_min: int, n_max: int, tau: float,
        grid: int = 1, limit_per_k: Optional[int] = None,
        seed: int = 0,
    ) -> "PartitionTable":
        """``limit_per_k`` caps the per-k partition count by *uniform
        subsampling* of the enumerated space (deterministic), not by
        lexicographic truncation (which would bias the table toward
        left-heavy boundaries)."""
        m = len(w)
        rng = np.random.default_rng(seed)
        # enumerate generously, subsample down to the limit
        enum_cap = None if limit_per_k is None else 50 * limit_per_k
        rows: List[Tuple[int, ...]] = []
        counts: List[int] = []
        # "up to n_max" intervals: a j-interval partition can serve a k-node
        # cluster (j ≤ k) iff it fits the k-cap; j below k/(1+tau) can never
        # fit, so enumerate j from that bound upward.
        j_lo = max(1, int(np.ceil(n_min / (1.0 + tau) - _EPS)))
        any_feasible_per_k = {k: False for k in range(n_min, n_max + 1)}
        Sw = prefix_sum(np.asarray(w, dtype=np.float64))
        W = float(Sw[-1])
        for j in range(j_lo, n_max + 1):
            # enumerate against the loosest cap this j could ever face:
            # cap(k_loosest) = (1+tau)·W/k_loosest expressed as a j-cap
            k_loosest = max(j, n_min)
            tau_eff = (1.0 + tau) * j / k_loosest - 1.0
            parts = grid_partitions(w, j, tau_eff, grid=grid,
                                    limit=enum_cap)
            if not parts and grid > 1:
                parts = grid_partitions(w, j, tau_eff, grid=1,
                                        limit=enum_cap)
            if limit_per_k is not None and len(parts) > limit_per_k:
                idx = rng.choice(len(parts), limit_per_k, replace=False)
                parts = [parts[i] for i in sorted(idx)]
            rows.extend(parts)
            counts.extend([j] * len(parts))
        if not rows:
            raise Infeasible(f"no balanced partition at any count, tau={tau}")
        K = max(len(r) - 1 for r in rows)
        Q = len(rows)
        bounds = np.full((Q, K + 1), m, dtype=np.int64)
        bounds[:, 0] = 0
        for i, r in enumerate(rows):
            bounds[i, : len(r)] = r
        loads = np.diff(Sw[bounds], axis=1)
        max_load = loads.max(axis=1)
        by_k: Dict[int, np.ndarray] = {}
        counts_a = np.asarray(counts)
        for k in range(n_min, n_max + 1):
            by_k[k] = np.nonzero(counts_a == k)[0]
        table = PartitionTable(m=m, n_counts=counts, bounds=bounds,
                               by_k=by_k, max_load=max_load, total_w=W,
                               tau=tau, n_min=n_min, n_max=n_max)
        for k in range(n_min, n_max + 1):
            if len(table.feasible_rows(k)) == 0:
                raise Infeasible(
                    f"no balanced partition for k={k}, tau={tau}")
        return table

    @property
    def Q(self) -> int:
        return self.bounds.shape[0]

    @property
    def K(self) -> int:
        return self.bounds.shape[1] - 1


# ---------------------------------------------------------------------------
# Batched pairwise non-crossing matching gain
# ---------------------------------------------------------------------------

def pairwise_gain_matrix(
    a_bounds: np.ndarray, b_bounds: np.ndarray, Ss: np.ndarray,
    chunk: int = 256,
) -> np.ndarray:
    """gain[i, j] = max non-crossing matching gain between partitions
    a_bounds[i] and b_bounds[j].  Batched LCS-style DP, O(K^2) sequential
    steps, each vectorized over a [chunk, Qb] pair block.

    This is the numpy reference for the Pallas ``interval_gain`` kernel.
    """
    Qa, K1 = a_bounds.shape
    Qb, K2 = b_bounds.shape
    Ka, Kb = K1 - 1, K2 - 1
    Ss = np.asarray(Ss, dtype=np.float64)
    out = np.empty((Qa, Qb), dtype=np.float64)
    b_lo = Ss[b_bounds[:, :-1]]                      # [Qb, Kb] prefix at lo
    b_hi = Ss[b_bounds[:, 1:]]
    for c0 in range(0, Qa, chunk):
        c1 = min(c0 + chunk, Qa)
        A = a_bounds[c0:c1]
        a_lo = Ss[A[:, :-1]][:, None, :, None]        # [C,1,Ka,1]
        a_hi = Ss[A[:, 1:]][:, None, :, None]
        ov = np.minimum(a_hi, b_hi[None, :, None, :]) - np.maximum(
            a_lo, b_lo[None, :, None, :]
        )                                             # [C,Qb,Ka,Kb]
        np.maximum(ov, 0.0, out=ov)
        # DP over (i, j); g has shape [C, Qb]
        prev = np.zeros((c1 - c0, Qb, Kb + 1))
        for i in range(1, Ka + 1):
            cur = np.zeros_like(prev)
            for j in range(1, Kb + 1):
                cur[:, :, j] = np.maximum(
                    np.maximum(prev[:, :, j], cur[:, :, j - 1]),
                    prev[:, :, j - 1] + ov[:, :, i - 1, j - 1],
                )
            prev = cur
        out[c0:c1] = prev[:, :, Kb]
    return out


# ---------------------------------------------------------------------------
# PMC — projected migration cost, value iteration (Fig. 16)
# ---------------------------------------------------------------------------

@dataclass
class PMCResult:
    table: PartitionTable
    values: np.ndarray          # C[P, k], [Q, n_range] (MDP state incl. k)
    cost: np.ndarray            # pairwise migration cost, [Q, Q]
    iterations: int
    gamma: float
    mtm: MTM


def pmc(
    table: PartitionTable,
    s: np.ndarray,
    mtm: MTM,
    gamma: float,
    tol: float = 1e-6,
    max_iters: int = 10_000,
    gain_fn=pairwise_gain_matrix,
) -> PMCResult:
    """Value-iterate the projected migration cost.

    MDP state = (partition, cluster size k): a j-interval partition may run
    on any k ≥ j whose cap it satisfies (idle nodes = SSM's free nodes), so
    the chain row is k's, not j's:

        C[P, k] = Σ_k' M[k,k'] · min_{P' feasible@k'} (c(P→P') + γ·C[P',k'])

    ``gain_fn`` computes the batched pairwise matching gain — swap in the
    Pallas kernel wrapper (kernels.ops.pairwise_gain) to run the hot loop on
    TPU; the numpy reference is the default.
    """
    Ss = prefix_sum(s)
    total_state = float(Ss[-1])
    gain = gain_fn(table.bounds, table.bounds, Ss)
    cost = total_state - gain
    np.maximum(cost, 0.0, out=cost)

    Q = table.Q
    nk = mtm.size
    feas = {k: table.feasible_rows(k) for k in range(mtm.n_min,
                                                     mtm.n_max + 1)}
    V = np.zeros((Q, nk), dtype=np.float64)
    it = 0
    if gamma == 0.0:
        max_iters = 1  # single sweep fixes V = E[min immediate cost]
    for it in range(1, max_iters + 1):
        # best next-step cost into each feasible cluster size
        best_to_k = np.full((Q, nk), np.inf)
        for k, idx in feas.items():
            ki = k - mtm.n_min
            tgt = cost[:, idx] + gamma * V[idx, ki][None, :]
            best_to_k[:, ki] = tgt.min(axis=1)
        Vn = best_to_k @ mtm.probs.T            # [Q, nk]: E over next k'
        delta = float(np.abs(Vn - V).max())
        V = Vn
        if delta < tol * max(1.0, total_state):
            break
    return PMCResult(table=table, values=V, cost=cost, iterations=it,
                     gamma=gamma, mtm=mtm)


# ---------------------------------------------------------------------------
# Runtime planner
# ---------------------------------------------------------------------------

def mtm_aware_plan(
    old: Assignment,
    n_new: int,
    s: np.ndarray,
    pmc_result: PMCResult,
    gain_fn=None,
) -> MigrationPlan:
    """Definition 2.8: minimize immediate cost + gamma * projected cost.

    Immediate cost is computed against the *concrete* old assignment (its
    node ids matter for the first hop); the projected cost is a pure function
    of the target partition (Lemma 4.2), looked up from the PMC table.

    ``gain_fn`` (same signature as ``pairwise_gain_matrix``; pass
    ``kernels.ops.pairwise_gain`` — interpret=True Pallas on CPU, native on
    TPU) batches the old-vs-candidate interval-gain scoring, the inner loop
    of this planner.  The kernel scores in f32, so it is used to *prune*:
    only candidates within a conservative error margin of the best f32 value
    are re-scored with the exact f64 ``match_gain``, in ascending row order,
    preserving the exact tie-break of the pure-python path bit-for-bit.
    The f32 DP accumulates ≤ K adds/maxes of values bounded by total_state,
    so |g32 − g64| ≤ K·eps32·total_state ≈ 1e-5·total_state at K=64; the
    margin below is two orders of magnitude wider.
    """
    table = pmc_result.table
    idx = table.feasible_rows(n_new)
    if len(idx) == 0:
        raise Infeasible(f"PMC table has no partitions for n'={n_new}")
    s = np.asarray(s, dtype=np.float64)
    Ss = prefix_sum(s)
    total_state = float(Ss[-1])
    old_items = old.nonempty()
    ki = n_new - pmc_result.mtm.n_min
    if gain_fn is not None and len(idx) > 1:
        a_bounds = np.concatenate(
            [[iv[0] for _, iv in old_items], [old.m]]).astype(np.int64)
        g32 = np.asarray(
            gain_fn(a_bounds[None, :], table.bounds[idx], Ss),
            dtype=np.float64)[0]
        val32 = (total_state - g32) + pmc_result.gamma * \
            pmc_result.values[idx, ki]
        margin = 1e-3 * max(1.0, total_state)
        idx = idx[val32 <= float(val32.min()) + margin]
    best_val, best_row = np.inf, -1
    for row in idx:
        bounds = [int(b) for b in table.bounds[row]]
        # strip padded tail (repeated m) down to the real boundary list
        while len(bounds) > 2 and bounds[-2] == table.m:
            bounds.pop()
        g, _ = match_gain(old_items, bounds, Ss)
        val = (total_state - g) + pmc_result.gamma * \
            pmc_result.values[row, ki]
        if val < best_val - 1e-12:
            best_val, best_row = val, row
    bounds = [int(b) for b in table.bounds[best_row]]
    while len(bounds) > 2 and bounds[-2] == table.m:
        bounds.pop()
    new = realize_partition(old, bounds, s, n_new)
    return _plan(old, new, s)
