"""ElasticPlanner — the framework-facing facade over the paper's algorithms.

A planner turns (current assignment, target node count, workload/state
statistics) into a MigrationPlan.  Policies:

    ssm        exact optimal single-step migration (paper §3, production
               default; backend="auto" — jit DP above _AUTO_JIT_MIN_M tasks)
    ssm_jit    same optimum, forced jit-compiled lax.scan DP (core/ssm_jit)
    ssm_numpy  same optimum, forced reference numpy DP (paper Fig. 14)
    mtm     MTM-aware: immediate + gamma-discounted projected cost (paper §4.2)
    simple  Simple_SSM oracle (paper Fig. 12 equivalent; small instances)
    adhoc   Storm-default analogue (paper's baseline)
    greedy  left-to-right trim heuristic

The planner also owns the tau schedule (the paper lets the user retune tau
per migration, §2.1) and the workload estimator hook used by the elastic
controller (runtime/elastic.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from .baselines import adhoc, greedy_trim
from .intervals import Assignment
from .mtm import MTM, PMCResult, PartitionTable, mtm_aware_plan, pmc
from .ssm import Infeasible, MigrationPlan, simple_ssm, ssm

Policy = Callable[[Assignment, int, np.ndarray, np.ndarray, float], MigrationPlan]

POLICIES = {
    "ssm": ssm,
    "ssm_jit": functools.partial(ssm, backend="jit"),
    "ssm_numpy": functools.partial(ssm, backend="numpy"),
    "simple": simple_ssm,
    "adhoc": adhoc,
    "greedy": greedy_trim,
}


@dataclass
class TauSchedule:
    """Per-migration load-balance threshold.  The paper suggests tightening
    tau when scaling up (latency-sensitive) and loosening it when rebalances
    thrash (§2.1)."""

    base: float = 1.2
    grow: Optional[float] = None      # tau when n' > n
    shrink: Optional[float] = None    # tau when n' < n

    def __call__(self, n_old: int, n_new: int) -> float:
        if n_new > n_old and self.grow is not None:
            return self.grow
        if n_new < n_old and self.shrink is not None:
            return self.shrink
        return self.base


@dataclass
class ElasticPlanner:
    policy: str = "ssm"
    tau: TauSchedule = field(default_factory=TauSchedule)
    # MTM-aware machinery (lazily built on first use)
    mtm: Optional[MTM] = None
    gamma: float = 0.8
    pmc_grid: int = 1
    pmc_limit_per_k: Optional[int] = 20_000
    # a pre-built PMC table (offline phase output); when set, "mtm" planning
    # uses it directly instead of rebuilding per workload snapshot
    fixed_pmc: Optional[PMCResult] = None
    # batched gain backend for mtm_aware_plan's scoring loop (e.g.
    # kernels.ops.pairwise_gain to route it through the Pallas kernel)
    mtm_gain_fn: Optional[Callable] = None
    _pmc: Optional[PMCResult] = None
    _pmc_key: Optional[tuple] = None

    def prepare(self, w: np.ndarray, s: np.ndarray, n_min: int, n_max: int,
                tau: Optional[float] = None) -> Optional[PMCResult]:
        """Precompute the PMC table (paper's offline phase).  No-op for
        non-MTM policies."""
        if self.policy != "mtm":
            return None
        tau = self.tau.base if tau is None else tau
        key = (len(w), float(np.asarray(w).sum()), n_min, n_max, tau,
               self.gamma, self.pmc_grid)
        if self._pmc is not None and self._pmc_key == key:
            return self._pmc
        if self.mtm is None:
            self.mtm = MTM.uniform(n_min, n_max)
        table = PartitionTable.build(
            np.asarray(w, dtype=np.float64), n_min, n_max, tau,
            grid=self.pmc_grid, limit_per_k=self.pmc_limit_per_k,
        )
        self._pmc = pmc(table, np.asarray(s, dtype=np.float64),
                        self.mtm, self.gamma)
        self._pmc_key = key
        return self._pmc

    # When a τ is infeasible (a single hot bucket exceeds the cap), relax it
    # geometrically up to relax_tau_max — the paper's "the user may decide to
    # loosen τ" (§2.1) as an automatic controller policy.
    relax_tau_max: float = 8.0

    def plan(
        self,
        old: Assignment,
        n_new: int,
        w: np.ndarray,
        s: np.ndarray,
        tau: Optional[float] = None,
    ) -> MigrationPlan:
        w = np.asarray(w, dtype=np.float64)
        s = np.asarray(s, dtype=np.float64)
        n_old = sum(1 for lo, hi in old.intervals if hi > lo)
        t = self.tau(n_old, n_new) if tau is None else tau
        if self.policy == "mtm":
            res = self.fixed_pmc
            if res is None:
                res = self.prepare(
                    w, s, min(n_old, n_new),
                    max(n_old, n_new,
                        self.mtm.n_max if self.mtm else n_new), tau=t)
            return mtm_aware_plan(old, n_new, s, res,
                                  gain_fn=self.mtm_gain_fn)
        fn = POLICIES.get(self.policy)
        if fn is None:
            raise ValueError(f"unknown policy {self.policy!r}")
        while True:
            try:
                return fn(old, n_new, w, s, t)
            except Infeasible:
                if t >= self.relax_tau_max:
                    raise
                t = min(t * 1.5 + 0.1, self.relax_tau_max)
