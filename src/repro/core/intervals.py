"""Task intervals, assignments, and gain/cost primitives (paper §2).

Tasks are 0-indexed ``j ∈ [0, m)``.  A node's *task interval* is half-open
``[lo, hi)``; an empty interval is ``(t, t)``.  The old assignment's nonempty
intervals must be disjoint and collectively cover ``[0, m)`` (paper §2.1).

A *partition* is a tuple of ``k+1`` nondecreasing boundaries
``(0, b1, ..., m)`` describing ``k`` ordered contiguous intervals.

All planner-side code is numpy (it runs on the controller host, like the
paper's Nimbus-side strategy computation); the device-side executors and the
PMC hot loop live elsewhere (``repro.runtime``, ``repro.kernels``).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

Interval = Tuple[int, int]

# Relative tolerance used for load-balance feasibility checks so that integer
# workloads compare exactly (cap is a float (1+tau)W/n').
_EPS = 1e-9


def feasible_tol(cap: float) -> float:
    """Canonical feasibility tolerance for the balance cap.

    An interval [a, b) fits the cap iff ``Sw[b] - Sw[a] <= feasible_tol(cap)``
    — *this exact expression*, prefix-sum difference against this exact
    tolerance.  Every feasibility decision in the planner must go through this
    predicate: a running-sum accumulator (``acc += w[b]``) rounds differently
    from ``Sw[b] - Sw[a]`` by a few ulps, which is enough to make two solvers
    disagree on feasibility when a single task weighs exactly ``(1+tau)W/n'``
    (the Infeasible-inconsistency bug this helper fixes).
    """
    return cap * (1 + _EPS) + _EPS


def prefix_sum(v: np.ndarray) -> np.ndarray:
    """Length m+1 prefix sums with S[0] = 0; measure of [lo,hi) = S[hi]-S[lo]."""
    v = np.asarray(v, dtype=np.float64)
    out = np.zeros(v.shape[0] + 1, dtype=np.float64)
    np.cumsum(v, out=out[1:])
    return out


def measure(S: np.ndarray, lo: int, hi: int) -> float:
    """Total (weight or state size) of tasks in [lo, hi) given prefix sums."""
    if hi <= lo:
        return 0.0
    return float(S[hi] - S[lo])


def overlap(a: Interval, b: Interval) -> Interval:
    """Intersection of two intervals (may be empty: lo >= hi)."""
    return (max(a[0], b[0]), min(a[1], b[1]))


def overlap_measure(S: np.ndarray, a: Interval, b: Interval) -> float:
    lo, hi = overlap(a, b)
    return measure(S, lo, hi)


def balance_cap(W: float, n_nodes: int, tau: float) -> float:
    """Per-node workload cap (Definition 2.1): (1+tau) * W / n."""
    if n_nodes <= 0:
        raise ValueError("n_nodes must be >= 1")
    return (1.0 + tau) * W / n_nodes


@dataclass(frozen=True)
class Assignment:
    """A task-to-node assignment: node i owns ``intervals[i]``.

    Node identity is positional.  ``intervals`` may contain empty intervals
    (new nodes before a grow migration, removed nodes after a shrink).
    """

    m: int
    intervals: Tuple[Interval, ...]

    # -- constructors -----------------------------------------------------
    @staticmethod
    def from_boundaries(m: int, boundaries: Sequence[int]) -> "Assignment":
        bs = list(boundaries)
        ivs = tuple((int(bs[i]), int(bs[i + 1])) for i in range(len(bs) - 1))
        return Assignment(m=m, intervals=ivs)

    # -- basic accessors ---------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return len(self.intervals)

    def nonempty(self) -> Tuple[Tuple[int, Interval], ...]:
        """(node_id, interval) for nonempty intervals, sorted by interval lo."""
        items = [(i, iv) for i, iv in enumerate(self.intervals) if iv[1] > iv[0]]
        items.sort(key=lambda t: t[1][0])
        return tuple(items)

    def validate(self) -> None:
        """Nonempty intervals must be disjoint and cover [0, m)."""
        items = self.nonempty()
        pos = 0
        for _, (lo, hi) in items:
            if lo != pos:
                raise ValueError(f"intervals not contiguous at {pos}: got {lo}")
            if hi <= lo:
                raise ValueError("empty interval leaked into nonempty()")
            pos = hi
        if pos != self.m:
            raise ValueError(f"intervals cover [0,{pos}) but m={self.m}")

    def node_loads(self, w: np.ndarray) -> np.ndarray:
        Sw = prefix_sum(w)
        return np.array([measure(Sw, lo, hi) for lo, hi in self.intervals])

    def owner_of(self) -> np.ndarray:
        """owner[j] = node id owning task j.  Requires a valid assignment."""
        owner = np.full(self.m, -1, dtype=np.int64)
        for i, (lo, hi) in enumerate(self.intervals):
            owner[lo:hi] = i
        return owner

    def padded(self, n_total: int) -> "Assignment":
        """Pad with empty intervals up to n_total nodes."""
        if n_total < self.n_nodes:
            raise ValueError("cannot shrink by padding")
        extra = tuple((self.m, self.m) for _ in range(n_total - self.n_nodes))
        return Assignment(self.m, self.intervals + extra)


def migration_gain(old: Assignment, new: Assignment, s: np.ndarray) -> float:
    """Total state size that does NOT move (Definition 3.1)."""
    if old.m != new.m:
        raise ValueError("mismatched m")
    Ss = prefix_sum(s)
    n = max(old.n_nodes, new.n_nodes)
    o, nw = old.padded(n), new.padded(n)
    return float(
        sum(
            overlap_measure(Ss, o.intervals[i], nw.intervals[i])
            for i in range(n)
        )
    )


def migration_cost(old: Assignment, new: Assignment, s: np.ndarray) -> float:
    """Total state size that moves between nodes (Definition 2.2)."""
    Ss = prefix_sum(s)
    total = measure(Ss, 0, old.m)
    return total - migration_gain(old, new, s)


def moved_tasks(old: Assignment, new: Assignment) -> np.ndarray:
    """Boolean mask of tasks whose owner changes."""
    return old.owner_of() != new.padded(max(old.n_nodes, new.n_nodes)).owner_of()


def satisfies_balance(
    assignment_or_bounds, w: np.ndarray, n_target: int, tau: float
) -> bool:
    """Definition 2.1 with cap computed for ``n_target`` nodes."""
    Sw = prefix_sum(w)
    cap = balance_cap(float(Sw[-1]), n_target, tau)
    if isinstance(assignment_or_bounds, Assignment):
        ivs = assignment_or_bounds.intervals
    else:
        bs = list(assignment_or_bounds)
        ivs = [(bs[i], bs[i + 1]) for i in range(len(bs) - 1)]
    tol = feasible_tol(cap)
    return all(measure(Sw, lo, hi) <= tol for lo, hi in ivs)


# ---------------------------------------------------------------------------
# Greedy covers (used by SSM for n_min and zero-gain filler construction).
# ---------------------------------------------------------------------------

def max_feasible_ends(Sw: np.ndarray, tol: float,
                      starts: np.ndarray) -> np.ndarray:
    """b[i] = largest b in [starts[i], m] with Sw[b] - Sw[starts[i]] <= tol.

    Vectorized: a searchsorted estimate (which evaluates ``Sw[a] + tol``, a
    *different* float expression) corrected by +-1 steps against the canonical
    predicate, so the result is exact w.r.t. ``Sw[b] - Sw[a] <= tol``.
    """
    starts = np.asarray(starts, dtype=np.int64)
    m = len(Sw) - 1
    b = np.searchsorted(Sw, Sw[starts] + tol, side="right") - 1
    b = np.clip(b, starts, m)
    while True:
        over = (b > starts) & (Sw[b] - Sw[starts] > tol)
        if not over.any():
            break
        b[over] -= 1
    while True:
        under = (b < m) & (Sw[np.minimum(b + 1, m)] - Sw[starts] <= tol)
        if not under.any():
            break
        b[under] += 1
    return b


def min_feasible_starts(Sw: np.ndarray, tol: float,
                        ends: np.ndarray) -> np.ndarray:
    """a[i] = smallest a in [0, ends[i]] with Sw[ends[i]] - Sw[a] <= tol.

    Dual of :func:`max_feasible_ends`; same canonical-predicate correction.
    """
    ends = np.asarray(ends, dtype=np.int64)
    a = np.searchsorted(Sw, Sw[ends] - tol, side="left")
    a = np.clip(a, 0, ends)
    while True:
        over = (a < ends) & (Sw[ends] - Sw[a] > tol)
        if not over.any():
            break
        a[over] += 1
    while True:
        under = (a > 0) & (Sw[ends] - Sw[np.maximum(a - 1, 0)] <= tol)
        if not under.any():
            break
        a[under] -= 1
    return a


def next_jump(w: np.ndarray, cap: float) -> np.ndarray:
    """nxt[a] = largest b (a <= b <= m) with weight([a,b)) <= cap.

    nxt[a] == a means task a alone exceeds the cap, which makes any
    contiguous partition infeasible.  Uses the canonical prefix-sum predicate
    (``feasible_tol``) so it agrees bit-for-bit with every other feasibility
    check in the planner.
    """
    m = len(w)
    Sw = prefix_sum(w)
    return max_feasible_ends(Sw, feasible_tol(cap), np.arange(m + 1))


def min_cover_counts(nxt: np.ndarray) -> np.ndarray:
    """cnt[a] = min #intervals (each <= cap) covering [a, m); inf if infeasible."""
    m = len(nxt) - 1
    INF = np.iinfo(np.int64).max // 2
    cnt = np.full(m + 1, INF, dtype=np.int64)
    cnt[m] = 0
    for a in range(m - 1, -1, -1):
        if nxt[a] > a and cnt[nxt[a]] < INF:
            cnt[a] = 1 + cnt[nxt[a]]
    return cnt


def greedy_boundaries(nxt: np.ndarray, lo: int, hi: int) -> list:
    """Greedy split of [lo, hi) into the minimum number of cap-feasible
    intervals; returns boundary list [lo, ..., hi].  Raises if infeasible."""
    bs = [lo]
    a = lo
    while a < hi:
        b = min(int(nxt[a]), hi)
        if b <= a:
            raise ValueError("single task exceeds balance cap; infeasible")
        bs.append(b)
        a = b
    return bs


# ---------------------------------------------------------------------------
# Partition enumeration (OMS / PMC).  Strictly increasing boundaries (no
# empty intervals: an empty interval is never useful for the optimum and
# bloats the MDP state space).
# ---------------------------------------------------------------------------

def enumerate_balanced_partitions(
    w: np.ndarray, k: int, tau: float, limit: Optional[int] = None
) -> Iterator[Tuple[int, ...]]:
    """Yield boundary tuples (0, b1, ..., m) of cap-feasible partitions of
    [0, m) into exactly k nonempty intervals."""
    m = len(w)
    Sw = prefix_sum(w)
    tol = feasible_tol(balance_cap(float(Sw[-1]), k, tau))
    count = 0

    def rec(start: int, parts_left: int, acc: Tuple[int, ...]):
        nonlocal count
        if limit is not None and count >= limit:
            return
        if parts_left == 1:
            if Sw[m] - Sw[start] <= tol:
                count += 1
                yield acc + (m,)
            return
        # next boundary b: start < b <= m - (parts_left - 1)
        for b in range(start + 1, m - parts_left + 2):
            if Sw[b] - Sw[start] > tol:
                break
            yield from rec(b, parts_left - 1, acc + (b,))

    yield from rec(0, k, (0,))


def count_balanced_partitions(w: np.ndarray, k: int, tau: float) -> int:
    """DP count of cap-feasible partitions into k nonempty intervals."""
    m = len(w)
    Sw = prefix_sum(w)
    tol = feasible_tol(balance_cap(float(Sw[-1]), k, tau))
    # cnt[j][b] = #ways to split [0, b) into j feasible intervals
    cnt = np.zeros((k + 1, m + 1), dtype=np.int64)
    cnt[0][0] = 1
    for j in range(1, k + 1):
        for b in range(1, m + 1):
            lo = int(np.searchsorted(Sw, Sw[b] - tol, side="left"))
            cnt[j][b] = cnt[j - 1][lo:b].sum()
    return int(cnt[k][m])


# ---------------------------------------------------------------------------
# Non-crossing interval matching (used by OMS edge costs, MTM runtime step,
# and as the reference for the kernels/interval_gain Pallas kernel).
# ---------------------------------------------------------------------------

def match_gain(
    old_items: Sequence[Tuple[int, Interval]],
    new_bounds: Sequence[int],
    Ss: np.ndarray,
) -> Tuple[float, list]:
    """Max total gain of assigning the ordered new intervals (given by
    ``new_bounds``) to distinct old nodes, plus the matching itself.

    The optimal bipartite matching between two families of disjoint ordered
    intervals is non-crossing (crossing pairs cannot both have positive
    gain), so an LCS-style DP is exact:
        g[i][j] = max(g[i-1][j], g[i][j-1], g[i-1][j-1] + ov(i, j)).

    Returns (gain, pairs) where pairs = [(old_pos, new_pos), ...] for matched
    pairs with positive overlap.
    """
    n_old = len(old_items)
    k = len(new_bounds) - 1
    g = np.zeros((n_old + 1, k + 1), dtype=np.float64)
    choice = np.zeros((n_old + 1, k + 1), dtype=np.int8)
    for i in range(1, n_old + 1):
        lo_i, hi_i = old_items[i - 1][1]
        for j in range(1, k + 1):
            ov = overlap_measure(
                Ss, (lo_i, hi_i), (new_bounds[j - 1], new_bounds[j])
            )
            best, c = g[i - 1][j], 1
            if g[i][j - 1] > best:
                best, c = g[i][j - 1], 2
            if g[i - 1][j - 1] + ov > best:
                best, c = g[i - 1][j - 1] + ov, 3
            g[i][j] = best
            choice[i][j] = c
    # reconstruct
    pairs = []
    i, j = n_old, k
    while i > 0 and j > 0:
        c = choice[i][j]
        if c == 1:
            i -= 1
        elif c == 2:
            j -= 1
        else:
            ov = overlap_measure(
                Ss,
                old_items[i - 1][1],
                (new_bounds[j - 1], new_bounds[j]),
            )
            if ov > 0:
                pairs.append((i - 1, j - 1))
            i, j = i - 1, j - 1
    pairs.reverse()
    return float(g[n_old][k]), pairs


def realize_partition(
    old: Assignment,
    new_bounds: Sequence[int],
    s: np.ndarray,
    n_target: int,
) -> "Assignment":
    """Turn a target *partition* into a concrete *assignment* by matching its
    intervals to old nodes to maximize gain (paper §4.1 line 3), assigning
    unmatched intervals to free nodes.

    The result has ``max(old.n_nodes, n_target)`` positional nodes; nodes not
    given an interval receive the empty interval (they are the removed nodes
    when shrinking).
    """
    Ss = prefix_sum(s)
    old_items = old.nonempty()
    _, pairs = match_gain(old_items, new_bounds, Ss)
    k = len(new_bounds) - 1
    n_total = max(old.n_nodes, n_target)
    ivs: list = [(old.m, old.m)] * n_total
    taken_new = set()
    taken_old = set()
    for old_pos, new_pos in pairs:
        node_id = old_items[old_pos][0]
        ivs[node_id] = (int(new_bounds[new_pos]), int(new_bounds[new_pos + 1]))
        taken_new.add(new_pos)
        taken_old.add(node_id)
    free_nodes = [i for i in range(n_total) if i not in taken_old]
    free_ivs = [j for j in range(k) if j not in taken_new]
    # Any leftover interval goes to any unused node; gain stays optimal (see
    # core/ssm.py docstring for the argument), order is deterministic.
    for node_id, j in zip(free_nodes, free_ivs):
        ivs[node_id] = (int(new_bounds[j]), int(new_bounds[j + 1]))
    if len(free_ivs) > len(free_nodes):
        raise AssertionError("more intervals than nodes")
    return Assignment(old.m, tuple(ivs))
