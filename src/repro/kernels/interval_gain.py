"""Batched non-crossing interval matching gain — the PAPER's compute
hot-spot as a Pallas kernel.

PMC (paper Fig. 16) needs the pairwise migration cost between every pair of
balanced partitions: cost(P,P') = total_state − maxgain(P,P'), where
maxgain is the non-crossing matching optimum, an LCS-style DP.  The paper
runs this on a Spark cluster for "hundreds of minutes" (Fig. 6); here each
(tile_a × tile_b) block of partition pairs runs the DP entirely in VMEM,
vectorized across the pair tile on the VPU.

Inputs are prefix-sum values at interval boundaries (a_lo/a_hi [Qa, Ka]):
the overlap measure of intervals (i, j) is
    max(0, min(a_hi[i], b_hi[j]) − max(a_lo[i], b_lo[j]))
computed on the fly — no [Ka×Kb] overlap tensor ever hits HBM.

DP state: g [ta, tb, Kb+1] f32 in VMEM, in-place row sweep with the
carried-diagonal trick (old g[j-1] is the fori carry).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(alo_ref, ahi_ref, blo_ref, bhi_ref, out_ref, g_ref, *,
            Ka: int, Kb: int):
    ta = alo_ref.shape[0]
    tb = blo_ref.shape[0]
    g_ref[...] = jnp.zeros_like(g_ref)

    def row(i, _):
        a_lo = alo_ref[:, i][:, None]                    # [ta, 1]
        a_hi = ahi_ref[:, i][:, None]

        def col(j, diag_old):
            b_lo = blo_ref[:, j][None, :]                # [1, tb]
            b_hi = bhi_ref[:, j][None, :]
            ov = jnp.maximum(
                jnp.minimum(a_hi, b_hi) - jnp.maximum(a_lo, b_lo), 0.0)
            up = g_ref[:, :, j + 1]                      # prev row, same col
            left = g_ref[:, :, j]                        # new row, col-1
            new = jnp.maximum(jnp.maximum(up, left), diag_old + ov)
            g_ref[:, :, j + 1] = new
            return up                                    # old g[j] = next diag

        jax.lax.fori_loop(0, Kb, col, g_ref[:, :, 0])
        return 0

    jax.lax.fori_loop(0, Ka, row, 0)
    out_ref[...] = g_ref[:, :, Kb].astype(out_ref.dtype)


def interval_gain_pallas(a_lo: jax.Array, a_hi: jax.Array,
                         b_lo: jax.Array, b_hi: jax.Array, *,
                         tile_a: int = 8, tile_b: int = 128,
                         interpret: bool = False) -> jax.Array:
    """a_lo/a_hi [Qa, Ka], b_lo/b_hi [Qb, Kb] (f32 prefix values) ->
    gain [Qa, Qb]."""
    Qa, Ka = a_lo.shape
    Qb, Kb = b_lo.shape
    ta = min(tile_a, Qa)
    tb = min(tile_b, Qb)
    # Pad Q dims to tile multiples with all-zero rows (lo = hi = 0, i.e.
    # fabricated empty intervals).  This is sound — the final slice
    # ``out[:Qa, :Qb]`` removes every cell a padded row can influence:
    # the DP state g[ia, jb, :] of pair (ia, jb) is updated only from
    # g[ia, jb, :] and the boundary values of a-row ia / b-row jb (all
    # kernel ops are elementwise over the [ta, tb] pair tile), so output
    # cell (i, j) is a function of exactly (a_lo[i], a_hi[i], b_lo[j],
    # b_hi[j]) — padded rows never couple into real (i < Qa, j < Qb)
    # cells.  (They'd be harmless even if they did: an empty [0, 0]
    # interval overlaps nothing, max(0, min(hi,0) − max(lo,0)) = 0, for
    # the monotone prefix values lo ≥ 0 used here — the same argument
    # that makes the callers' K-dim padding with repeated-m boundaries,
    # lo = hi = Ss[m], contribute zero gain.)  test_kernels.py
    # exercises non-multiple Qa/Qb against the numpy reference.
    pa = (-Qa) % ta
    pb = (-Qb) % tb
    if pa:
        pad = jnp.zeros((pa, Ka), a_lo.dtype)
        a_lo, a_hi = jnp.concatenate([a_lo, pad]), jnp.concatenate([a_hi, pad])
    if pb:
        pad = jnp.zeros((pb, Kb), b_lo.dtype)
        b_lo, b_hi = jnp.concatenate([b_lo, pad]), jnp.concatenate([b_hi, pad])
    na, nb = a_lo.shape[0] // ta, b_lo.shape[0] // tb
    kernel = functools.partial(_kernel, Ka=Ka, Kb=Kb)
    out = pl.pallas_call(
        kernel,
        grid=(na, nb),
        in_specs=[
            pl.BlockSpec((ta, Ka), lambda i, j: (i, 0)),
            pl.BlockSpec((ta, Ka), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, Kb), lambda i, j: (j, 0)),
            pl.BlockSpec((tb, Kb), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ta, tb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((na * ta, nb * tb), jnp.float32),
        scratch_shapes=[pltpu.VMEM((ta, tb, Kb + 1), jnp.float32)],
        interpret=interpret,
    )(a_lo, a_hi, b_lo, b_hi)
    return out[:Qa, :Qb]
