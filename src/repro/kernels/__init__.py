"""Pallas TPU kernels for the framework's compute hot-spots.

kernels/<name>.py — pl.pallas_call + BlockSpec (TPU target)
ops.py            — jit'd wrappers (interpret=True on CPU; ref fallback)
ref.py            — pure-jnp oracles

Kernels: flash_attention (train/prefill), decode_attention (long-KV decode),
rglru_scan (recurrentgemma), mamba_scan (falcon-mamba), interval_gain (the
paper's PMC pairwise-cost hot loop).
"""
from .ops import (
    decode_attention, flash_attention, mamba_scan, pairwise_gain, rglru_scan,
)

__all__ = ["decode_attention", "flash_attention", "mamba_scan",
           "pairwise_gain", "rglru_scan"]
