"""Single-token decode attention Pallas kernel (flash-decode style).

One new token per sequence attends a long KV cache.  Grid (B·Hkv, ns):
the KV sequence is blocked; each step folds one KV block into the online
softmax held in VMEM scratch for the G grouped q heads.  Invalid cache
slots (ring buffers, unwritten tail, out-of-window) carry position -1 in
``kv_pos`` and are masked — identical semantics to
models.layers.decode_attention (the oracle).

VMEM per step: k,v blocks (s_blk×hd×2B ≈ 128 KB at 512×128) + acc [G, hd]
— tiny; the schedule is HBM-bandwidth-bound by design (decode roofline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, qpos_ref, o_ref,
            acc_ref, m_ref, l_ref, *, ns: int, scale: float):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [s_blk, hd]
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, s_blk]
    kvp = pos_ref[0]                                     # [s_blk]
    valid = jnp.logical_and(kvp >= 0, kvp <= qpos_ref[0])
    s = jnp.where(valid[None, :], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
    q_pos: jax.Array, kv_pos: jax.Array, *,
    s_block: int = 512, interpret: bool = False,
) -> jax.Array:
    """q [B,H,hd]; caches [B,Hkv,S,hd]; q_pos [B]; kv_pos [B,S] (-1 invalid).

    Returns o [B,H,hd].
    """
    B, H, hd = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    s_blk = min(s_block, S)
    assert S % s_blk == 0
    ns = S // s_blk
    qg = q.reshape(B, Hkv, G, hd)
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, ns=ns, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda bh, si: (bh // Hkv, bh % Hkv, 0, 0)),
            pl.BlockSpec((1, 1, s_blk, hd),
                         lambda bh, si: (bh // Hkv, bh % Hkv, si, 0)),
            pl.BlockSpec((1, 1, s_blk, hd),
                         lambda bh, si: (bh // Hkv, bh % Hkv, si, 0)),
            pl.BlockSpec((1, s_blk), lambda bh, si: (bh // Hkv, si)),
            pl.BlockSpec((1,), lambda bh, si: (bh // Hkv,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda bh, si: (bh // Hkv, bh % Hkv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, kv_pos, q_pos)
    return out.reshape(B, H, hd)
