"""Blocked flash attention Pallas kernel (TPU target).

Schedule: grid (B·H, nq, nk) — online-softmax accumulation in VMEM scratch;
the causal/window band is enforced by SKIPPING out-of-band kv blocks with
``pl.when`` (on TPU a skipped grid step costs grid overhead, not FLOPs —
the honest-causal schedule the pure-jnp path approximates with folding).

GQA without materializing repeated KV: the K/V BlockSpec index maps collapse
the q-head grid index onto its kv head (h // group).

VMEM working set per grid step (default blocks, hd=128, f32 scratch):
    q (512×128×2B) + k,v (512×128×2B each) + acc (512×128×4B) + m,l
    ≈ 0.75 MB — comfortably inside the ~16 MB v5e VMEM budget with
    double-buffered pipelining.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, q_blk: int,
            kv_blk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    q_start = qi * q_blk
    k_start = ki * kv_blk

    # band check (static per grid step shape; dynamic predicate)
    in_band = jnp.bool_(True)
    if causal:
        in_band = jnp.logical_and(in_band, k_start <= q_start + q_blk - 1)
    if window:
        in_band = jnp.logical_and(
            in_band, k_start + kv_blk - 1 > q_start - window)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [q_blk, hd]
        k = k_ref[0, 0].astype(jnp.float32)               # [kv_blk, hd]
        v = v_ref[0, 0]                                   # [kv_blk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # [q_blk, kv_blk]
        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_blk, kv_blk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_blk, kv_blk), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v,
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0,
    q_block: int = 512, kv_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q [B,H,Sq,hd]; k,v [B,Hkv,Skv,hd] -> o [B,H,Sq,hd]."""
    B, H, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = H // Hkv
    q_blk = min(q_block, Sq)
    kv_blk = min(kv_block, Skv)
    assert Sq % q_blk == 0 and Skv % kv_blk == 0
    nq, nk = Sq // q_blk, Skv // kv_blk
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_blk=q_blk, kv_blk=kv_blk, nk=nk)
    grid = (B * H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, hd),
                         lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((1, 1, kv_blk, hd),
                         lambda bh, qi, ki: (bh // H, (bh % H) // G, ki, 0)),
            pl.BlockSpec((1, 1, kv_blk, hd),
                         lambda bh, qi, ki: (bh // H, (bh % H) // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, hd),
                               lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, hd), jnp.float32),   # acc
            pltpu.VMEM((q_blk,), jnp.float32),      # running max
            pltpu.VMEM((q_blk,), jnp.float32),      # running sum
        ],
        interpret=interpret,
    )(q, k, v)
