"""Public jit'd wrappers for the Pallas kernels.

On a TPU backend the kernels compile natively; everywhere else (this CPU
container) they execute via ``interpret=True`` — same kernel body, Python
semantics — so correctness is validated on CPU while the BlockSpec/VMEM
schedule targets TPU.  ``prefer_pallas=False`` (or non-TPU + interpret-off)
falls back to the pure-jnp oracle — the production model code calls these
entry points, so flipping one flag moves the hot loops onto the kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .decode_attention import decode_attention_pallas
from .flash_attention import flash_attention_pallas
from .interval_gain import interval_gain_pallas
from .mamba_scan import mamba_scan_pallas
from .rglru_scan import rglru_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, q_block=512,
                    kv_block=512, use_pallas=None, interpret=None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        return _ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window)
    itp = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  q_block=q_block, kv_block=kv_block,
                                  interpret=itp)


def decode_attention(q, k_cache, v_cache, q_pos, kv_pos, *, s_block=512,
                     use_pallas=None, interpret=None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        return _ref.decode_attention_ref(q, k_cache, v_cache, q_pos, kv_pos)
    itp = (not _on_tpu()) if interpret is None else interpret
    return decode_attention_pallas(q, k_cache, v_cache, q_pos, kv_pos,
                                   s_block=s_block, interpret=itp)


def rglru_scan(a, b, h0, *, s_block=256, d_block=512, use_pallas=None,
               interpret=None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        return _ref.rglru_scan_ref(a, b, h0)
    itp = (not _on_tpu()) if interpret is None else interpret
    return rglru_scan_pallas(a, b, h0, s_block=s_block, d_block=d_block,
                             interpret=itp)


def mamba_scan(a, b, c, h0, *, s_block=128, d_block=512, use_pallas=None,
               interpret=None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        return _ref.mamba_scan_ref(a, b, c, h0)
    itp = (not _on_tpu()) if interpret is None else interpret
    return mamba_scan_pallas(a, b, c, h0, s_block=s_block, d_block=d_block,
                             interpret=itp)


def pairwise_gain(bounds_a: np.ndarray, bounds_b: np.ndarray,
                  Ss: np.ndarray, *, use_pallas=None, interpret=None,
                  tile_a=8, tile_b=128) -> np.ndarray:
    """Drop-in accelerated replacement for
    core.mtm.pairwise_gain_matrix(a_bounds, b_bounds, Ss) — the PMC hot
    loop.  Converts boundary indices to prefix values and runs the batched
    DP kernel."""
    Ss = jnp.asarray(Ss, jnp.float32)
    a = jnp.asarray(bounds_a)
    b = jnp.asarray(bounds_b)
    a_lo, a_hi = Ss[a[:, :-1]], Ss[a[:, 1:]]
    b_lo, b_hi = Ss[b[:, :-1]], Ss[b[:, 1:]]
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        out = _ref.interval_gain_ref(a_lo, a_hi, b_lo, b_hi)
    else:
        itp = (not _on_tpu()) if interpret is None else interpret
        out = interval_gain_pallas(a_lo, a_hi, b_lo, b_hi, tile_a=tile_a,
                                   tile_b=tile_b, interpret=itp)
    return np.asarray(out, dtype=np.float64)
