"""RG-LRU linear-recurrence Pallas kernel.

h_t = a_t ⊙ h_{t-1} + b_t over [B, S, D], elementwise in D.

Grid (B, nd, ns) with ns innermost: the carry h lives in VMEM scratch and
persists across sequence chunks (TPU grid steps execute in order); within a
chunk the recurrence runs as a sequential fori over rows — each step is a
[d_blk]-wide VPU op, so the kernel is bandwidth-bound reading a,b and
writing h exactly once (the pure-jnp associative scan reads/writes the
chunk O(log S) times — this kernel is the memory-roofline fix, see
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, h_ref, carry_ref, *, s_blk: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)

    def step(t, h):
        a_t = a_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)
        h = a_t * h + b_t
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    carry_ref[...] = jax.lax.fori_loop(0, s_blk, step, carry_ref[...])


def rglru_scan_pallas(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                      s_block: int = 256, d_block: int = 512,
                      interpret: bool = False):
    """a, b [B,S,D]; h0 [B,D] -> h [B,S,D] (h[:, -1] is the final state)."""
    B, S, D = a.shape
    s_blk = min(s_block, S)
    d_blk = min(d_block, D)
    assert S % s_blk == 0 and D % d_blk == 0
    ns, nd = S // s_blk, D // d_blk
    kernel = functools.partial(_kernel, s_blk=s_blk)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, ns),
        in_specs=[
            pl.BlockSpec((1, s_blk, d_blk), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, s_blk, d_blk), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, d_blk), lambda bi, di, si: (bi, di)),
        ],
        out_specs=pl.BlockSpec((1, s_blk, d_blk),
                               lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((d_blk,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
