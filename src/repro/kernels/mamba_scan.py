"""Mamba-1 selective-scan Pallas kernel.

h_t = a_t ⊙ h_{t-1} + b_t over [B, S, D, N] (N = SSM state per channel),
plus the contraction y_t = Σ_n C_t[n] · h_t[:, n] fused in-kernel so the
[B,S,D,N] state sequence is NEVER materialized in HBM — the "hardware-aware
scan" of the Mamba paper re-tiled for VMEM: gates a,b stream in blocked
[s_blk, d_blk, N] tiles, the carry h [d_blk, N] persists in VMEM scratch,
and only y [B,S,D] is written back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref, carry_ref, *,
            s_blk: int, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)

    def step(t, h):
        a_t = a_ref[0, t].astype(jnp.float32)             # [d_blk, N]
        b_t = b_ref[0, t].astype(jnp.float32)
        h = a_t * h + b_t
        c_t = c_ref[0, t].astype(jnp.float32)             # [N]
        y_ref[0, t] = (h * c_t[None, :]).sum(axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, s_blk, step, carry_ref[...])
    carry_ref[...] = h

    @pl.when(si == ns - 1)
    def _fin():
        hout_ref[0] = h.astype(hout_ref.dtype)


def mamba_scan_pallas(a: jax.Array, b: jax.Array, c: jax.Array,
                      h0: jax.Array, *, s_block: int = 128,
                      d_block: int = 512, interpret: bool = False):
    """a, b [B,S,D,N]; c [B,S,N]; h0 [B,D,N] ->
    (y [B,S,D] = Σ_n c·h, h_last [B,D,N])."""
    B, S, D, N = a.shape
    s_blk = min(s_block, S)
    d_blk = min(d_block, D)
    assert S % s_blk == 0 and D % d_blk == 0
    ns, nd = S // s_blk, D // d_blk
    kernel = functools.partial(_kernel, s_blk=s_blk, ns=ns)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, ns),
        in_specs=[
            pl.BlockSpec((1, s_blk, d_blk, N),
                         lambda bi, di, si: (bi, si, di, 0)),
            pl.BlockSpec((1, s_blk, d_blk, N),
                         lambda bi, di, si: (bi, si, di, 0)),
            pl.BlockSpec((1, s_blk, N), lambda bi, di, si: (bi, si, 0)),
            pl.BlockSpec((1, d_blk, N), lambda bi, di, si: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s_blk, d_blk), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, d_blk, N), lambda bi, di, si: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), a.dtype),
            jax.ShapeDtypeStruct((B, D, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_blk, N), jnp.float32)],
        interpret=interpret,
    )(a, b, c, h0)
