"""Pure-jnp oracles for every Pallas kernel (single source of truth shared
with the model layers where one exists)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import attention_reference, decode_attention
from repro.models.recurrence import linear_scan


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q [B,H,Sq,hd]; k,v [B,Hkv,Skv,hd] — same layout as the kernel."""
    o = attention_reference(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window)
    return o.transpose(0, 2, 1, 3)


def decode_attention_ref(q, k_cache, v_cache, q_pos, kv_pos):
    """q [B,H,hd]; caches [B,Hkv,S,hd] — kernel layout; oracle reuses the
    model-layer decode attention ([B,S,Hkv,hd] layout)."""
    o = decode_attention(q[:, None].transpose(0, 1, 2, 3),
                         k_cache.transpose(0, 2, 1, 3),
                         v_cache.transpose(0, 2, 1, 3), q_pos, kv_pos)
    return o[:, 0]


def rglru_scan_ref(a, b, h0):
    h, _ = linear_scan(a, b, h0)
    return h


def mamba_scan_ref(a, b, c, h0):
    """Materializing reference: h [B,S,D,N] then y = Σ_n c·h."""
    h, h_last = linear_scan(a, b, h0)
    y = jnp.einsum("bsdn,bsn->bsd", h.astype(jnp.float32),
                   c.astype(jnp.float32)).astype(a.dtype)
    return y, h_last


def interval_gain_ref(a_lo, a_hi, b_lo, b_hi):
    """Non-crossing matching DP, vectorized over all partition pairs."""
    Qa, Ka = a_lo.shape
    Qb, Kb = b_lo.shape
    ov = jnp.maximum(
        jnp.minimum(a_hi[:, None, :, None], b_hi[None, :, None, :])
        - jnp.maximum(a_lo[:, None, :, None], b_lo[None, :, None, :]),
        0.0)                                             # [Qa,Qb,Ka,Kb]
    g = jnp.zeros((Qa, Qb, Kb + 1), jnp.float32)
    for i in range(Ka):
        def col(j, carry):
            g_cur, diag_old = carry
            new = jnp.maximum(
                jnp.maximum(g_cur[:, :, j + 1], g_cur[:, :, j]),
                diag_old + ov[:, :, i, j])
            old = g_cur[:, :, j + 1]
            return g_cur.at[:, :, j + 1].set(new), old
        (g, _) = jax.lax.fori_loop(
            0, Kb, col, (g, g[:, :, 0]))
    return g[:, :, Kb]
