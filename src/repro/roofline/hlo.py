"""Loop-aware HLO text analysis for roofline terms.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a model
that ``lax.scan``s over 64 layers reports 1/64 of the real FLOPs (verified
empirically in tests/test_roofline.py).  The dry run therefore needs its own
analyzer.  This module parses ``compiled.as_text()`` into computations,
builds a per-computation symbol table (post-optimization HLO references
operands by name only), resolves *execution multipliers* (while-loop trip
counts are static constants embedded in jax-scan condition computations),
and accumulates:

* ``dot_flops``         — 2 · prod(result dims) · contracted size, per dot
* ``collective_bytes``  — wire bytes per device for all-gather / all-reduce /
                          reduce-scatter / all-to-all / collective-permute
                          (ring-algorithm accounting over the replica group)
* ``hbm_bytes``         — Σ result bytes of ops at fusion boundaries ×2
                          (read≈write) — an estimate of HBM traffic

All numbers are per-device totals (SPMD: the module is the per-device
program).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# "%name = <result-type> <opname>(<rest>"   (result may be a tuple; tuple
# bodies can contain /*index=N*/ comments, hence [^()] rather than [^=])
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"((?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$")
_CALL_ONE = re.compile(
    r"(body|condition|to_apply|calls)=%?([\w\.\-]+)")
_CALL_SET = re.compile(
    r"(calls|branch_computations)=\{([^}]*)\}")
_RG_RE = re.compile(r"replica_groups=\{(.*?)\}\}?")
_RG_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


def _shapes_in(txt: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> int:
    return sum(math.prod(d) * _DTYPE_BYTES[t] if d else _DTYPE_BYTES[t]
               for t, d in shapes)


@dataclass
class OpLine:
    name: str
    result_txt: str      # result type text (array or tuple)
    op: str
    rest: str            # operands + attributes text


@dataclass
class Computation:
    name: str
    lines: List[OpLine] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> result txt


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{") and "->" in s and ("(" in s):
                toks = s.split()
                name = toks[1] if toks[0] == "ENTRY" else toks[0]
                name = name.lstrip("%")
                # strip any attached "(":
                name = name.split("(")[0]
                cur = Computation(name=name)
            continue
        if s == "}" or s.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, res, op, rest = m.groups()
        cur.lines.append(OpLine(name=name, result_txt=res, op=op, rest=rest))
        cur.symbols[name] = res
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _called_comps(rest: str) -> List[str]:
    out: List[str] = []
    for m in _CALL_ONE.finditer(rest):
        out.append(m.group(2))
    for m in _CALL_SET.finditer(rest):
        for nm in m.group(2).split(","):
            nm = nm.strip().lstrip("%")
            if nm:
                out.append(nm)
    return out


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Max integer constant reachable from the condition computation (exact
    for jax scans: the bound is a constant compared against the induction
    variable, possibly inside a wrapped-compare fusion)."""
    best = 1
    seen = set()

    def rec(name: str):
        nonlocal best
        if name in seen or name not in comps:
            return
        seen.add(name)
        for ln in comps[name].lines:
            if ln.op == "constant":
                mm = re.search(r"constant\((-?\d+)\)", ln.op + "(" + ln.rest)
                if mm:
                    best = max(best, int(mm.group(1)))
            for mm in re.finditer(r"constant\((-?\d+)\)", ln.rest):
                best = max(best, int(mm.group(1)))
            for sub in _called_comps(ln.rest):
                rec(sub)

    rec(cond_name)
    return best


def _operand_names(rest: str) -> List[str]:
    """Operand instruction names inside the call parens (up to the closing
    paren at depth 0)."""
    depth = 1
    out = []
    cur = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur += ch
    for m in re.finditer(r"%([\w\.\-]+)", cur):
        out.append(m.group(1))
    return out


def _dot_flops(ln: OpLine, comp: Computation) -> float:
    res = _shapes_in(ln.result_txt)
    if not res:
        return 0.0
    out_elems = math.prod(res[0][1]) if res[0][1] else 1
    ops = _operand_names(ln.rest)
    if not ops:
        return 0.0
    lhs_txt = comp.symbols.get(ops[0], "")
    lhs = _shapes_in(lhs_txt)
    if not lhs:
        return 0.0
    ldims = lhs[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln.rest)
    contracted = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            if d and int(d) < len(ldims):
                contracted *= ldims[int(d)]
    return 2.0 * out_elems * contracted


def _group_size(rest: str, total_devices: int) -> int:
    m = _RG_V2.search(rest)
    if m:
        return max(1, int(m.group(2)))
    m = _RG_RE.search(rest)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(1, len(ids))
    return total_devices


def _collective_bytes(op: str, ln: OpLine, total_devices: int) -> float:
    """Per-device wire bytes (ring algorithm over the replica group)."""
    n = _group_size(ln.rest, total_devices)
    if n <= 1:
        return 0.0
    shapes = _shapes_in(ln.result_txt)
    out_bytes = _bytes_of(shapes)
    if op == "all-gather":
        return out_bytes * (n - 1) / n
    if op == "all-reduce":
        return 2.0 * out_bytes * (n - 1) / n
    if op == "reduce-scatter":
        return out_bytes * (n - 1)          # result is one shard
    if op in ("all-to-all", "ragged-all-to-all"):
        return out_bytes * (n - 1) / n
    if op == "collective-permute":
        return float(out_bytes)
    return 0.0


# while results are loop-carried state updated in place (donated/aliased);
# counting the whole tuple per step would double-charge the body's writes
_SKIP_MEM = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "copy", "after-all", "add-dependency", "domain",
             "while"}


@dataclass
class HloCosts:
    dot_flops: float = 0.0
    collective_bytes: float = 0.0
    hbm_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)
    while_trips: List[int] = field(default_factory=list)


def _inplace_update_bytes(comps, body_name: str) -> Optional[int]:
    """If a fusion body's root is a dynamic-update-slice — or a TUPLE whose
    elements are DUSes / passthroughs (XLA's scan-ys assembly) — the fusion
    writes in place: traffic = the update slices, not the whole buffers.
    Returns the slice bytes, or None if the root isn't update-shaped."""
    body = comps.get(body_name)
    if body is None or not body.lines:
        return None
    by_name = {ln.name: ln for ln in body.lines}

    def resolve(line):
        """Follow convert/bitcast/copy chains (XLA CPU's FloatNormalization
        wraps bf16 DUS in f32 converts — a CPU lowering artifact; the TPU
        target updates in place)."""
        seen = 0
        while line is not None and line.op in ("convert", "bitcast", "copy") \
                and seen < 8:
            ops_ = _operand_names(line.rest)
            line = by_name.get(ops_[0]) if ops_ else None
            seen += 1
        return line

    def dus_update_bytes(line) -> Optional[int]:
        ops_ = _operand_names(line.rest)
        if len(ops_) < 2:
            return None
        return _bytes_of(_shapes_in(body.symbols.get(ops_[1], "")))

    root = resolve(body.lines[-1])
    if root is None:
        return None
    if root.op == "dynamic-update-slice":
        return dus_update_bytes(root)
    if root.op != "tuple":
        return None
    total = 0
    for op_name in _operand_names(root.rest):
        ln = resolve(by_name.get(op_name))
        if ln is None:  # parameter passthrough: no traffic
            continue
        if ln.op == "dynamic-update-slice":
            b = dus_update_bytes(ln)
            if b is None:
                return None
            total += b
        elif ln.op in ("parameter", "get-tuple-element"):
            continue
        else:
            total += _bytes_of(_shapes_in(ln.result_txt))
    return total


def analyze(hlo: str, total_devices: int = 1) -> HloCosts:
    comps = parse_computations(hlo)
    fusion_bodies: set = set()
    for c in comps.values():
        for ln in c.lines:
            if ln.op == "fusion":
                fusion_bodies.update(_called_comps(ln.rest))

    entry = None
    for name in comps:
        if name.startswith("main"):
            entry = name
            break
    if entry is None:
        referenced: set = set()
        for c in comps.values():
            for ln in c.lines:
                referenced.update(_called_comps(ln.rest))
        for name in comps:
            if name not in referenced:
                entry = name
                break

    costs = HloCosts()

    def visit(name: str, mult: float, stack: tuple):
        if name not in comps or name in stack:
            return
        c = comps[name]
        in_fusion = name in fusion_bodies
        for ln in c.lines:
            base_op = ln.op.replace("-start", "") if ln.op.endswith("-start") \
                else ln.op
            if ln.op == "dot":
                costs.dot_flops += mult * _dot_flops(ln, c)
            elif base_op in COLLECTIVES:
                b = _collective_bytes(base_op, ln, total_devices)
                costs.collective_bytes += mult * b
                costs.collective_breakdown[base_op] = (
                    costs.collective_breakdown.get(base_op, 0.0) + mult * b)
                costs.collective_counts[base_op] = (
                    costs.collective_counts.get(base_op, 0) + 1)
            if not in_fusion and ln.op not in _SKIP_MEM:
                bytes_ = None
                if ln.op == "dynamic-update-slice":
                    # in-place DUS: traffic = the updated slice
                    ops_ = _operand_names(ln.rest)
                    upd = c.symbols.get(ops_[1], "") if len(ops_) > 1 else ""
                    bytes_ = _bytes_of(_shapes_in(upd))
                elif ln.op == "fusion":
                    for sub in _called_comps(ln.rest):
                        b = _inplace_update_bytes(comps, sub)
                        if b is not None:
                            bytes_ = b
                            break
                if bytes_ is None:
                    bytes_ = _bytes_of(_shapes_in(ln.result_txt))
                costs.hbm_bytes += 2.0 * mult * bytes_
            if ln.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ln.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln.rest)
                trips = _trip_count(comps, mc.group(1)) if mc else 1
                costs.while_trips.append(trips)
                if mb:
                    visit(mb.group(1), mult * trips, stack + (name,))
            elif ln.op in ("fusion", "call", "custom-call", "map", "reduce",
                           "reduce-window", "scatter", "sort",
                           "select-and-scatter", "conditional",
                           "async-start"):
                for sub in _called_comps(ln.rest):
                    visit(sub, mult, stack + (name,))
        return

    if entry:
        visit(entry, 1.0, ())
    return costs
