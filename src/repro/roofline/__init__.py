from .hlo import HloCosts, analyze, parse_computations
from .terms import (
    HBM_BW, ICI_BW, PEAK_FLOPS, migration_transfer_s, model_flops,
    roofline_terms,
)

__all__ = [
    "HloCosts", "analyze", "parse_computations",
    "HBM_BW", "ICI_BW", "PEAK_FLOPS", "migration_transfer_s", "model_flops",
    "roofline_terms",
]
