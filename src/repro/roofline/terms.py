"""Roofline terms (deliverable g).

Hardware constants (TPU v5e target, per the assignment):
    peak bf16     197 TFLOP/s per chip
    HBM bandwidth 819 GB/s per chip
    ICI           ~50 GB/s per link; a v5e chip has 4 ICI links on the 2D
                  torus — we charge collectives against ONE link's bandwidth
                  (conservative; ring collectives stream over one logical
                  ring unless XLA splits them).

Terms per (arch × shape × mesh), from the loop-aware HLO analysis (all
per-device quantities — SPMD modules are per-device programs):

    compute_s    = dot_flops / PEAK_FLOPS
    memory_s     = hbm_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW

plus MODEL_FLOPS (analytic 6·N·D / 2·N·D useful compute) and the useful /
compiled compute ratio that catches remat and masked-attention waste.
"""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic useful FLOPs for the whole cell (all devices).

    Matmul-participating active params: active_params() minus the embedding
    gather table (tied embeddings count once — as the unembedding matmul).
    Attention score/AV FLOPs added separately (they are not param FLOPs).
    """
    N = cfg.active_params()
    emb = cfg.vocab_size * cfg.d_model
    N_mm = N - emb if not cfg.tie_embeddings else N
    N_enc = cfg.encoder_params()
    N_dec = N_mm - N_enc          # decoder-side matmul params
    B, S = shape.global_batch, shape.seq_len

    def self_attn_flops(tokens: float, ctx: float) -> float:
        if cfg.attn_free:
            return 0.0
        n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
        return tokens * n_attn * 4.0 * cfg.n_heads * cfg.hd * ctx  # QK+AV

    def cross_attn_flops(tokens: float) -> float:
        if not cfg.is_encoder_decoder:
            return 0.0
        return tokens * cfg.n_layers * 4.0 * cfg.n_heads * cfg.hd * \
            cfg.encoder_seq

    def encoder_flops() -> float:
        if not cfg.is_encoder_decoder:
            return 0.0
        toks = float(B * cfg.encoder_seq)
        return 2.0 * N_enc * toks + toks * cfg.encoder_layers * 4.0 * \
            cfg.n_heads * cfg.hd * cfg.encoder_seq

    if shape.kind == "train":
        tokens = float(B * S)
        ctx = min(S, cfg.window) if cfg.window else S / 2.0
        fwd = (2.0 * N_dec * tokens + self_attn_flops(tokens, ctx)
               + cross_attn_flops(tokens) + encoder_flops())
        return 3.0 * fwd
    if shape.kind == "prefill":
        tokens = float(B * S)
        ctx = min(S, cfg.window) if cfg.window else S / 2.0
        return (2.0 * N_dec * tokens + self_attn_flops(tokens, ctx)
                + cross_attn_flops(tokens) + encoder_flops())
    # decode: one new token per sequence against a ctx-long cache; the
    # encoder is NOT re-run (cross K/V live in the cache)
    tokens = float(B)
    ctx = min(S, cfg.window) if cfg.window else S
    cross = tokens * cfg.n_layers * 4.0 * cfg.n_heads * cfg.hd * \
        cfg.encoder_seq if cfg.is_encoder_decoder else 0.0
    return 2.0 * N_dec * tokens + self_attn_flops(tokens, ctx) + cross


def migration_transfer_s(phase_link_bytes, interconnect: str = "ici"
                         ) -> float:
    """Roofline lower bound for a phased state migration.

    ``phase_link_bytes``: the busiest-link bytes of each executed phase
    (``MigrationReport.phase_link_bytes``) — a phase ends when its busiest
    link drains, and phases run back-to-back, so the predicted transfer
    time is the sum of per-phase busiest-link bytes over the interconnect
    bandwidth: ``ici`` for device-to-device resharding (one v5e link,
    matching the collective accounting above) or ``hbm`` for same-device
    row copies (gather + scatter both hit HBM, hence the factor 2).
    """
    if interconnect == "ici":
        return float(sum(b / ICI_BW for b in phase_link_bytes))
    if interconnect == "hbm":
        return float(sum(2.0 * b / HBM_BW for b in phase_link_bytes))
    raise ValueError(f"interconnect must be 'ici' or 'hbm', "
                     f"got {interconnect!r}")


def roofline_terms(cfg: ModelConfig, shape: ShapeConfig, costs,
                   n_devices: int) -> Dict[str, float]:
    compute_s = costs.dot_flops / PEAK_FLOPS
    memory_s = costs.hbm_bytes / HBM_BW
    collective_s = costs.collective_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    per_dev_useful = mf / n_devices
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops_total": mf,
        "useful_compute_ratio": (per_dev_useful / costs.dot_flops
                                 if costs.dot_flops else 0.0),
        "roofline_fraction": (per_dev_useful / PEAK_FLOPS) / total
        if total > 0 else 0.0,
        "step_time_lower_bound_s": total,
    }
