"""jax version compatibility shims.

The repo targets the modern jax API (``jax.shard_map`` with ``axis_names``/
``check_vma``, ``jax.sharding.get_abstract_mesh``); the container ships jax
0.4.37 where shard_map lives in ``jax.experimental.shard_map`` with the
older ``auto=``/``check_rep=`` partial-manual spelling and there is no
abstract-mesh accessor.  Everything version-dependent funnels through here.
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["shard_map", "get_abstract_mesh", "ambient_mesh"]


def ambient_mesh():
    """The mesh currently in scope via ``with mesh:`` (or None)."""
    try:  # modern API
        m = jax.sharding.get_abstract_mesh()
        if m is not None and tuple(getattr(m, "axis_names", ()) or ()):
            return m
    except AttributeError:
        pass
    try:  # jax<=0.4.x: the physical mesh held by the thread resource env
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # pragma: no cover
        pass
    return None


def get_abstract_mesh():
    """Compat alias for jax.sharding.get_abstract_mesh(); may return None."""
    return ambient_mesh()


def shard_map(f, *, mesh=None, in_specs, out_specs,
              axis_names: Optional[set] = None, check_vma: bool = False):
    """``jax.shard_map`` with partial-manual axes, on either jax API.

    ``axis_names`` is the set of mesh axes to be manual over (the modern
    spelling); on jax 0.4.x it is translated to ``auto = mesh axes -
    axis_names`` for ``jax.experimental.shard_map.shard_map``.  ``mesh``
    defaults to the ambient mesh.
    """
    if hasattr(jax, "shard_map"):  # modern API
        kwargs = dict(in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if mesh is not None:
            kwargs["mesh"] = mesh
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map

    if mesh is None:
        mesh = ambient_mesh()
    if mesh is None:
        raise ValueError("shard_map compat path needs a mesh (explicit or "
                         "ambient `with mesh:`)")
    all_axes = set(mesh.axis_names)
    manual = all_axes if axis_names is None else set(axis_names)
    auto = frozenset(all_axes - manual)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
