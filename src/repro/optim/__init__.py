from .adamw import OptConfig, adamw_update, global_norm, init_opt_state, lr_at
from .compression import (
    compressed_psum_mean, dequantize_int8, init_error_state, quantize_int8,
)

__all__ = [
    "OptConfig", "adamw_update", "global_norm", "init_opt_state", "lr_at",
    "compressed_psum_mean", "dequantize_int8", "init_error_state",
    "quantize_int8",
]
