"""Error-feedback int8 gradient compression for the data-parallel all-reduce.

On 1000+ node clusters the DP gradient reduction is DCN-bound; int8
compression cuts the wire bytes 4× (vs f32 master grads / 2× vs bf16).  The
scheme is EF-SGD style:

    v   = g + err                 (carry the previous round's residual)
    q   = round(v / scale) int8   (per-tensor scale)
    out = mean over DP of dequantized q
    err'= v − dequant(q)          (residual stays local; bounded, no drift)

``compressed_psum_mean`` is written against a named mesh axis and is used
inside ``shard_map`` train steps when ``grad_compression=True``; the int8
``all_gather`` is what lands in the HLO, so the roofline's collective term
sees the 4× byte reduction (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def quantize_int8(v: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(v)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def init_error_state(grads) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, F32), grads)


def compressed_psum_mean(grads, err_state, axis_name: str
                         ) -> Tuple[Any, Any]:
    """Mean-reduce grads over ``axis_name`` with int8 wire format.

    Must run inside shard_map with ``axis_name`` bound.  Returns
    (mean_grads f32, new_err_state).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, err):
        v = g.astype(F32) + err
        q, scale = quantize_int8(v)
        qg = jax.lax.all_gather(q, axis_name)          # int8 on the wire
        sg = jax.lax.all_gather(scale, axis_name)
        deq = qg.astype(F32) * sg.reshape((-1,) + (1,) * g.ndim)
        mean = deq.sum(axis=0) / n
        new_err = v - dequantize_int8(q, scale)
        return mean, new_err

    pairs = jax.tree_util.tree_map(one, grads, err_state)
    outer = jax.tree_util.tree_structure(grads)
    inner = jax.tree_util.tree_structure((0, 0))
    mean, err = jax.tree_util.tree_transpose(outer, inner, pairs)
    return mean, err
