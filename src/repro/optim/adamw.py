"""AdamW with mixed precision: bf16 working params, f32 master copy + f32
moments (the standard 14-bytes/param production layout).  Functional, pytree
native, GSPMD-friendly (state shardings derived in launch/shardings.py with
ZeRO-1 data-axis sharding).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac·lr."""
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros_like(p, dtype=F32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree_util.tree_map(
            lambda p: p.astype(F32), params),
        "m": jax.tree_util.tree_map(f32, params),
        "v": jax.tree_util.tree_map(f32, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32))) for x in leaves))


def adamw_update(grads, opt_state, params, cfg: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)

    def upd(g, m, v, master, p):
        g = g.astype(F32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        new_master = master - lr * (
            mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master)
        return m, v, new_master, new_master.astype(p.dtype)

    flat = jax.tree_util.tree_map(
        upd, grads, opt_state["m"], opt_state["v"], opt_state["master"],
        params)
    # unzip the per-leaf 4-tuples via tree_transpose (robust to empty
    # containers in the param structure)
    outer = jax.tree_util.tree_structure(params)
    inner = jax.tree_util.tree_structure((0, 0, 0, 0))
    m, v, master, new_params = jax.tree_util.tree_transpose(
        outer, inner, flat)
    new_state = {"step": step, "master": master, "m": m, "v": v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
