"""Plan/schedule verifier: the migration invariant catalog as named rules.

The paper's claim is that a migration plan is *correct by construction* —
coverage, balance, and conservation hold because the DP enforces them.
Every one of those guarantees is a property that can be checked against a
concrete ``MigrationPlan`` + schedule *before* anything executes, which is
exactly where migration bugs must be caught: they surface as silent state
loss or latency spikes, not crashes (Megaphone; Volnes et al.).

Rules (stable IDs — tests, CI, and docs refer to them):

``PLN001`` **move coverage & ownership** — the scheduled moves are exactly
    the plan's owner diff: every moving bucket shipped once, none dropped,
    none invented, no bucket owned twice; old/new assignments are valid
    contiguous covers of ``[0, m)`` and ``plan.old`` matches the live
    assignment when one is given.
``PLN002`` **round validity** — every batched_fluid round is a matching
    (≤1 send and ≤1 receive per node) and maximal: no schedulable link
    left idle while both endpoints were free.
``PLN003`` **byte conservation** — move sizes equal the priced bucket
    bytes (``DeviceBucketedState`` leaf pricing or the planner's ``s``),
    their sum equals ``plan.cost``, and ``gain + cost`` equals the total
    state (Definitions 2.2/3.1: nothing lost, nothing double-counted).
``PLN004`` **capacity feasibility** — every node's post-migration load is
    within the balance cap ``(1+τ)·W/n`` (Definition 2.1) at the τ the
    plan was made for (or the planner's relax ceiling when auto-relax is
    enabled).
``PLN005`` **window containment & own-transfer pauses** — pause windows
    lie inside ``[0, duration]``; non-moving buckets never pause;
    fluid/batched_fluid buckets pause exactly their own transfer;
    live/progressive windows open at 0 (paper §5.2 semantics).
``PLN006`` **permutation validity** — ``plan_to_permutation`` yields a
    true permutation of ``[0, m)`` that lays each new node's buckets out
    contiguously (the uniform-bucket dry-run/GSPMD layout).

Entry points: the fine-grained ``check_*`` functions return
``Finding`` lists; ``verify_migration`` composes the full catalog for one
plan the way the runtime would execute it (shared ``strategy_schedule`` /
``strategy_windows`` dispatch, so the verifier checks exactly the
schedule the runtime runs).  ``MigrationExecutor(verify="strict")`` and
the serving simulators / ``ControlLoop`` call these as an opt-in debug
hook; ``scripts/lint_plans.py`` is the CLI; the property tests in
``tests/`` call them as the shared oracle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import MigrationPlan, balance_cap, feasible_tol
from repro.runtime.migration import (
    move_list, plan_to_permutation, strategy_schedule,
)

PLN_RULES = {
    "PLN001": "move coverage & ownership: schedule is exactly the plan's "
              "owner diff; assignments are valid contiguous covers",
    "PLN002": "round validity: each batched_fluid round is a maximal "
              "matching (≤1 send, ≤1 recv per node)",
    "PLN003": "byte conservation: move bytes = priced bucket bytes; "
              "Σ moves = plan.cost; gain + cost = total state",
    "PLN004": "capacity feasibility: every new node load ≤ (1+τ)W/n "
              "(Definition 2.1)",
    "PLN005": "window containment & own-transfer pauses",
    "PLN006": "plan_to_permutation is a valid contiguous-layout "
              "permutation",
}

# byte quantities are sums of float64 leaf sizes; exact equality modulo
# accumulation order
_RTOL = 1e-9


@dataclass(frozen=True)
class Finding:
    """One violated rule, machine-readable."""

    rule: str                      # "PLN004"
    message: str
    context: dict = field(default_factory=dict)

    def __str__(self) -> str:
        return f"{self.rule}: {self.message}"


class PlanVerificationError(AssertionError):
    """A plan/schedule violated the invariant catalog (verify='strict')."""

    def __init__(self, findings: Sequence[Finding], where: str = ""):
        self.findings = list(findings)
        head = f"{where}: " if where else ""
        super().__init__(head + format_findings(self.findings))


def format_findings(findings: Sequence[Finding]) -> str:
    if not findings:
        return "clean"
    return f"{len(findings)} finding(s)\n" + "\n".join(
        f"  {f}" for f in findings)


def assert_clean(findings: Sequence[Finding], where: str = "") -> None:
    if findings:
        raise PlanVerificationError(findings, where=where)


def handle(findings: Sequence[Finding], verify: Optional[str],
           where: str = "") -> None:
    """Dispatch findings per the verify level: 'strict' raises, 'warn'
    prints to stderr, None/empty ignores."""
    if not findings or not verify:
        return
    if verify == "strict":
        raise PlanVerificationError(findings, where=where)
    import sys
    print(f"plancheck[{where}]: {format_findings(findings)}",
          file=sys.stderr)


def _close(a: float, b: float, scale: float = 0.0) -> bool:
    # `scale` widens the tolerance to O(ulp · total-state): gain/cost are
    # differences of large sums, so even an honest zero-move plan carries
    # a rounding residual proportional to Σs, not to the tiny value itself
    return abs(a - b) <= _RTOL * max(abs(a), abs(b), scale, 1.0)


# ---------------------------------------------------------------------------
# PLN001 (structure) + PLN003 (conservation) + PLN004 (feasibility)
# ---------------------------------------------------------------------------

def check_plan(plan: MigrationPlan, s: np.ndarray, *,
               w: Optional[np.ndarray] = None,
               tau: Optional[float] = None,
               n_target: Optional[int] = None,
               relax_tau_max: Optional[float] = None,
               expected_old=None) -> List[Finding]:
    """Structural + conservation + feasibility rules on the plan itself.

    ``w``/``tau`` enable PLN004 (skipped otherwise — the executor hook has
    no workload view).  ``n_target`` is the node count the cap divides by
    (defaults to the plan's active node count); ``relax_tau_max`` loosens
    the cap to the planner's auto-relax ceiling so plans that legitimately
    relaxed τ are not flagged.  ``expected_old`` pins ``plan.old`` to the
    live assignment (catches stale-plan bugs)."""
    out: List[Finding] = []
    s_arr = np.asarray(s, dtype=np.float64)
    if plan.old.m != plan.new.m:
        out.append(Finding("PLN001", f"old m={plan.old.m} != new "
                                     f"m={plan.new.m}",
                           {"old_m": plan.old.m, "new_m": plan.new.m}))
        return out
    structural = False
    for name, a in (("old", plan.old), ("new", plan.new)):
        try:
            a.validate()
        except ValueError as e:
            structural = True
            out.append(Finding(
                "PLN001", f"{name} assignment is not a contiguous cover "
                          f"of [0, {a.m}): {e}",
                {"assignment": name, "error": str(e)}))
    if expected_old is not None and \
            tuple(expected_old.intervals) != tuple(plan.old.intervals):
        out.append(Finding(
            "PLN001", "plan.old does not match the live assignment "
                      "(stale plan)",
            {"live": list(expected_old.intervals),
             "plan_old": list(plan.old.intervals)}))
    if structural:
        return out          # owner maps below would be garbage
    # PLN003: gain/cost recomputed from s must match the plan's claims,
    # and together account for every byte exactly once
    from repro.core import migration_cost, migration_gain
    gain = migration_gain(plan.old, plan.new, s_arr)
    cost = migration_cost(plan.old, plan.new, s_arr)
    total = float(s_arr.sum())
    for name, claimed, actual in (("cost", plan.cost, cost),
                                  ("gain", plan.gain, gain)):
        if not _close(claimed, actual, scale=total):
            out.append(Finding(
                "PLN003", f"plan.{name}={claimed:.6g} but recomputed "
                          f"{name} from s is {actual:.6g}",
                {"field": name, "claimed": claimed, "actual": actual}))
    if not _close(gain + cost, total):
        out.append(Finding(
            "PLN003", f"gain {gain:.6g} + cost {cost:.6g} != total state "
                      f"{total:.6g} (bytes lost or double-counted)",
            {"gain": gain, "cost": cost, "total": total}))
    # PLN004: Definition 2.1 at the plan's τ
    if w is not None and tau is not None:
        w_arr = np.asarray(w, dtype=np.float64)
        loads = [(i, float(w_arr[lo:hi].sum()))
                 for i, (lo, hi) in enumerate(plan.new.intervals)
                 if hi > lo]
        n = int(n_target) if n_target is not None else len(loads)
        tau_eff = float(tau) if relax_tau_max is None \
            else max(float(tau), float(relax_tau_max))
        cap = balance_cap(float(w_arr.sum()), max(n, 1), tau_eff)
        tol = feasible_tol(cap)
        for i, load in loads:
            if load > tol:
                out.append(Finding(
                    "PLN004", f"node {i} load {load:.6g} exceeds cap "
                              f"(1+{tau_eff:g})W/{n} = {cap:.6g}",
                    {"node": i, "load": load, "cap": cap, "tau": tau_eff,
                     "n": n}))
    return out


# ---------------------------------------------------------------------------
# PLN001 (coverage of a move list) + PLN003 (move pricing)
# ---------------------------------------------------------------------------

def _key(mv) -> Tuple[int, int, int]:
    return (int(mv.bucket), int(mv.src), int(mv.dst))


def check_moves(plan: MigrationPlan, s: np.ndarray,
                moves: Sequence) -> List[Finding]:
    """The move list is exactly the plan's owner diff, priced from ``s``."""
    out: List[Finding] = []
    s_arr = np.asarray(s, dtype=np.float64)
    derived = move_list(plan, s_arr)
    want = {_key(mv) for mv in derived}
    got: Dict[Tuple[int, int, int], int] = {}
    for mv in moves:
        got[_key(mv)] = got.get(_key(mv), 0) + 1
    by_bucket: Dict[int, int] = {}
    for (b, _s, _d), k in got.items():
        by_bucket[b] = by_bucket.get(b, 0) + k
    for b, k in sorted(by_bucket.items()):
        if k > 1:
            out.append(Finding(
                "PLN001", f"bucket {b} scheduled to move {k} times "
                          f"(duplicate ownership transfer)",
                {"bucket": b, "times": k}))
    for key in sorted(want - set(got)):
        out.append(Finding(
            "PLN001", f"move {key} (bucket, src, dst) required by the "
                      f"plan but missing from the schedule (dropped — "
                      f"silent state loss)",
            {"move": key}))
    for key in sorted(set(got) - want):
        out.append(Finding(
            "PLN001", f"move {key} scheduled but not in the plan's owner "
                      f"diff (invented move)", {"move": key}))
    total = 0.0
    for mv in moves:
        total += float(mv.nbytes)
        if _key(mv) in want and not _close(float(mv.nbytes),
                                           float(s_arr[mv.bucket])):
            out.append(Finding(
                "PLN003", f"bucket {mv.bucket} priced {mv.nbytes:.6g} B "
                          f"but its state is {float(s_arr[mv.bucket]):.6g}"
                          f" B", {"bucket": int(mv.bucket),
                                  "nbytes": float(mv.nbytes),
                                  "state": float(s_arr[mv.bucket])}))
    if set(got) == want and not any(f.rule == "PLN001" for f in out) \
            and not _close(total, plan.cost, scale=float(s_arr.sum())):
        out.append(Finding(
            "PLN003", f"Σ scheduled bytes {total:.6g} != plan.cost "
                      f"{plan.cost:.6g}",
            {"scheduled": total, "plan_cost": float(plan.cost)}))
    return out


# ---------------------------------------------------------------------------
# PLN001 (schedule coverage) + PLN002 (matching rounds)
# ---------------------------------------------------------------------------

def check_schedule(moves: Sequence, schedule: Sequence[Sequence],
                   mode: str) -> List[Finding]:
    """The phase/round structure ships exactly ``moves``; batched_fluid
    rounds are additionally maximal matchings (PLN002)."""
    out: List[Finding] = []
    flat = [mv for group in schedule for mv in group]
    want: Dict[Tuple[int, int, int], int] = {}
    for mv in moves:
        want[_key(mv)] = want.get(_key(mv), 0) + 1
    got: Dict[Tuple[int, int, int], int] = {}
    for mv in flat:
        got[_key(mv)] = got.get(_key(mv), 0) + 1
    for key in sorted(want):
        if got.get(key, 0) < want[key]:
            out.append(Finding(
                "PLN001", f"move {key} dropped by the {mode} schedule "
                          f"(state would silently never arrive)",
                {"move": key, "mode": mode}))
    for key in sorted(got):
        extra = got[key] - want.get(key, 0)
        if extra > 0:
            kind = "duplicated" if key in want else "invented"
            out.append(Finding(
                "PLN001", f"move {key} {kind} by the {mode} schedule",
                {"move": key, "mode": mode, "times": got[key]}))
    if mode != "batched_fluid":
        return out
    # PLN002: replay the rounds against the pending-link counts
    pending: Dict[Tuple[int, int], int] = {}
    for mv in moves:
        pending[(int(mv.src), int(mv.dst))] = \
            pending.get((int(mv.src), int(mv.dst)), 0) + 1
    for r, rnd in enumerate(schedule):
        if not len(rnd):
            out.append(Finding("PLN002", f"round {r} is empty",
                               {"round": r}))
            continue
        src_to_dst: Dict[int, int] = {}
        dst_to_src: Dict[int, int] = {}
        for mv in rnd:
            s_, d_ = int(mv.src), int(mv.dst)
            if src_to_dst.setdefault(s_, d_) != d_:
                out.append(Finding(
                    "PLN002", f"round {r}: node {s_} sends to both "
                              f"{src_to_dst[s_]} and {d_}",
                    {"round": r, "node": s_}))
            if dst_to_src.setdefault(d_, s_) != s_:
                out.append(Finding(
                    "PLN002", f"round {r}: node {d_} receives from both "
                              f"{dst_to_src[d_]} and {s_}",
                    {"round": r, "node": d_}))
        for (s_, d_), k in sorted(pending.items()):
            if k > 0 and s_ not in src_to_dst and d_ not in dst_to_src:
                out.append(Finding(
                    "PLN002", f"round {r} not maximal: link ({s_}, {d_}) "
                              f"had pending moves and both endpoints idle",
                    {"round": r, "link": (s_, d_), "pending": k}))
        for mv in rnd:
            lk = (int(mv.src), int(mv.dst))
            pending[lk] = pending.get(lk, 0) - 1
    return out


# ---------------------------------------------------------------------------
# PLN005 (windows)
# ---------------------------------------------------------------------------

def check_windows(moves: Sequence, un_from: np.ndarray,
                  un_until: np.ndarray, duration: float, freeze: float,
                  mode: str, bw_bytes_per_s: float, m: int
                  ) -> List[Finding]:
    """Pause windows are contained, own-transfer-sized where the strategy
    guarantees it, and touch only moving buckets."""
    out: List[Finding] = []
    un_from = np.asarray(un_from, dtype=np.float64)
    un_until = np.asarray(un_until, dtype=np.float64)
    eps = 1e-9 * max(1.0, abs(duration))
    moving = {int(mv.bucket): float(mv.nbytes) for mv in moves}
    width = un_until - un_from
    for j in range(m):
        if un_from[j] < -eps or un_from[j] > un_until[j] + eps:
            out.append(Finding(
                "PLN005", f"bucket {j} window [{un_from[j]:.6g}, "
                          f"{un_until[j]:.6g}) is malformed",
                {"bucket": j, "from": float(un_from[j]),
                 "until": float(un_until[j])}))
        elif un_until[j] > duration + eps:
            out.append(Finding(
                "PLN005", f"bucket {j} window ends at {un_until[j]:.6g}s, "
                          f"outside the migration interval "
                          f"[0, {duration:.6g}]",
                {"bucket": j, "until": float(un_until[j]),
                 "duration": float(duration)}))
        if j not in moving and width[j] > eps:
            out.append(Finding(
                "PLN005", f"bucket {j} does not move but is paused for "
                          f"{width[j]:.6g}s",
                {"bucket": j, "width": float(width[j])}))
    if mode == "kill_restart":
        if moves and freeze <= 0.0:
            out.append(Finding(
                "PLN005", "kill_restart with moves but no app freeze",
                {"freeze": float(freeze)}))
        return out
    for mv in moves:
        j = int(mv.bucket)
        own = float(mv.nbytes) / bw_bytes_per_s \
            if np.isfinite(bw_bytes_per_s) else 0.0
        tol = eps + 1e-9 * max(own, 1.0)
        if mode == "batched_fluid":
            # within a round every link ships sequentially, so the pause
            # is exactly the bucket's own transfer (Megaphone guarantee)
            if abs(width[j] - own) > tol:
                out.append(Finding(
                    "PLN005", f"bucket {j} pause {width[j]:.6g}s != its "
                              f"own transfer {own:.6g}s (batched_fluid "
                              f"guarantee)",
                    {"bucket": j, "pause": float(width[j]),
                     "own_transfer": own, "mode": mode}))
        elif mode == "fluid":
            # pause = own phase's [start, end): at least the bucket's own
            # transfer (nothing ships faster than the link)
            if width[j] < own - tol:
                out.append(Finding(
                    "PLN005", f"bucket {j} pause {width[j]:.6g}s shorter "
                              f"than its own transfer {own:.6g}s",
                    {"bucket": j, "pause": float(width[j]),
                     "own_transfer": own, "mode": mode}))
        elif mode in ("live", "progressive") and un_from[j] > eps:
            out.append(Finding(
                "PLN005", f"bucket {j} window opens at {un_from[j]:.6g}s "
                          f"but {mode} buckets stop when migration "
                          f"begins (§5.2)",
                {"bucket": j, "from": float(un_from[j]), "mode": mode}))
    return out


# ---------------------------------------------------------------------------
# PLN006 (permutation)
# ---------------------------------------------------------------------------

def check_permutation(plan: MigrationPlan,
                      perm: Optional[np.ndarray] = None) -> List[Finding]:
    """``perm`` (default: ``plan_to_permutation(plan)``) is a permutation
    of [0, m) laying each new node's buckets out contiguously."""
    out: List[Finding] = []
    m = plan.old.m
    if perm is None:
        perm = plan_to_permutation(plan)
    perm = np.asarray(perm)
    if len(perm) != m:
        out.append(Finding(
            "PLN006", f"permutation has {len(perm)} entries, expected {m}",
            {"len": int(len(perm)), "m": m}))
        return out
    counts = np.bincount(perm[(perm >= 0) & (perm < m)], minlength=m)
    dup = np.nonzero(counts > 1)[0]
    missing = np.nonzero(counts == 0)[0]
    oob = perm[(perm < 0) | (perm >= m)]
    if len(dup) or len(missing) or len(oob):
        out.append(Finding(
            "PLN006", f"not a permutation of [0, {m}): "
                      f"{len(dup)} duplicated, {len(missing)} missing, "
                      f"{len(oob)} out of range",
            {"duplicated": dup[:8].tolist(),
             "missing": missing[:8].tolist(),
             "out_of_range": np.asarray(oob)[:8].tolist()}))
        return out
    # contiguity: walking perm must visit each new interval as one run
    pos = 0
    n_total = max(plan.old.n_nodes, plan.new.n_nodes)
    for i, (lo, hi) in enumerate(plan.new.padded(n_total).intervals):
        run = perm[pos:pos + (hi - lo)]
        if not np.array_equal(run, np.arange(lo, hi)):
            out.append(Finding(
                "PLN006", f"new node {i}'s buckets [{lo}, {hi}) are not "
                          f"a contiguous run in the permutation",
                {"node": i, "interval": (int(lo), int(hi))}))
        pos += hi - lo
    return out


# ---------------------------------------------------------------------------
# The composed catalog
# ---------------------------------------------------------------------------

def verify_migration(plan: MigrationPlan, s: np.ndarray, sim=None,
                     mode: str = "live", max_inflight: int = 4,
                     fluid_batch: int = 1, *,
                     w: Optional[np.ndarray] = None,
                     tau: Optional[float] = None,
                     n_target: Optional[int] = None,
                     relax_tau_max: Optional[float] = None,
                     expected_old=None) -> List[Finding]:
    """Run the full PLN catalog on ``plan`` as strategy ``mode`` would
    execute it: derive the moves, build the schedule and windows through
    the same ``strategy_schedule``/``strategy_windows`` dispatch the
    runtime uses, and check every rule.  Returns all findings ([] =
    clean)."""
    from repro.runtime.serving import SimConfig, strategy_windows
    sim = sim if sim is not None else SimConfig()
    s_arr = np.asarray(s, dtype=np.float64)
    out = check_plan(plan, s_arr, w=w, tau=tau, n_target=n_target,
                     relax_tau_max=relax_tau_max, expected_old=expected_old)
    if any(f.rule == "PLN001" for f in out):
        return out          # derived moves/windows would be garbage
    moves = move_list(plan, s_arr)
    out += check_moves(plan, s_arr, moves)
    schedule = strategy_schedule(moves, s_arr, mode,
                                 max_inflight=max_inflight,
                                 fluid_batch=fluid_batch)
    out += check_schedule(moves, schedule, mode)
    un_from, un_until, duration, freeze = strategy_windows(
        moves, s_arr, sim, mode, max_inflight, fluid_batch, plan.old.m)
    out += check_windows(moves, un_from, un_until, duration, freeze, mode,
                         sim.bw_bytes_per_s, plan.old.m)
    out += check_permutation(plan)
    return out
