"""Static analysis over migration plans and the codebase itself.

Two checkers, both producing named machine-readable rules:

* ``plancheck`` — PLN001..PLN006: the invariant catalog a correct
  migration must satisfy (move coverage, matching rounds, byte
  conservation, capacity feasibility, window containment, permutation
  validity), runnable against any MigrationPlan + schedule *before*
  execution.  Wired as the opt-in ``verify="strict"`` debug hook of
  ``MigrationExecutor`` / the serving simulators / ``ControlLoop``, as
  the ``scripts/lint_plans.py`` CLI, and as the shared oracle the
  property tests call.
* ``jaxlint`` — JAX001..JAX006: an AST lint over the source tree with
  rules distilled from this repo's actual bug history (uint64/Python-int
  promotion, tracer leaks inside jit, numpy in scanned closures,
  unscoped x64 mutation, nondeterminism in planners, mutable defaults).

Rule IDs are stable: tests, CI, and suppression comments refer to them.
"""
_PLANCHECK = (
    "PLN_RULES", "Finding", "PlanVerificationError", "assert_clean",
    "check_moves", "check_permutation", "check_plan", "check_schedule",
    "check_windows", "format_findings", "verify_migration",
)
_JAXLINT = ("JAX_RULES", "LintFinding", "lint_file", "lint_paths")

__all__ = list(_PLANCHECK + _JAXLINT)


def __getattr__(name):
    # lazy (PEP 562): `python -m repro.analysis.jaxlint` must not import
    # the submodule twice (runpy warning), and importing the package must
    # not pull the runtime layer until a checker is actually used
    if name in _PLANCHECK:
        from . import plancheck
        return getattr(plancheck, name)
    if name in _JAXLINT:
        from . import jaxlint
        return getattr(jaxlint, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
