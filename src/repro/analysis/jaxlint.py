"""AST lint for the JAX footguns this repo has actually been bitten by.

Every rule is distilled from a real bug class in this codebase's history
(see docs/ARCHITECTURE.md "Invariants" and the PR log in CHANGES.md):

``JAX001`` **mixed uint64/Python-int arithmetic** — the PR 1 ``route()``
    overflow class: numpy silently promotes ``np.uint64 <op> python-int``
    to float64, corrupting hash arithmetic.  Flags a bare int literal
    ≥ 2³² used directly as a binary-op operand (unless the expression is
    wrapped in ``uint64(...)``), and any binary op mixing a
    ``uint64(...)`` call with a bare int literal.
``JAX002`` **tracer concretization** — ``.item()`` / ``float()`` /
    ``int()`` / ``bool()`` on a traced value inside a jit/``lax.scan``
    body raises ``ConcretizationTypeError`` only at trace time, on the
    shapes that reach it.
``JAX003`` **numpy inside traced code** — ``np.*`` calls in a
    jitted/scanned closure are silently constant-folded at trace time:
    correct-looking, wrong under new inputs.
``JAX004`` **unscoped x64 mutation** — ``config.update("jax_enable_x64",
    …)`` outside a guarded scope flips global precision for every module
    imported after it (the ``ssm_jit`` discipline).
``JAX005`` **nondeterminism in planner/scheduler modules** — wall clocks
    (``time.time``/``perf_counter``) and unseeded ``random`` /
    ``np.random`` calls in planning code break the differential tests'
    exact reproducibility.  Only applies to ``core/*`` and the runtime
    planner/scheduler modules.
``JAX006`` **mutable default arguments** — ``def f(x, acc=[])`` and
    dataclass fields ``x: list = []`` share one object across calls /
    instances; registries accrete state.  Use ``field(default_factory=…)``
    or ``None``.

Suppress a deliberate hit with a trailing (or immediately preceding)
comment naming the rule and the reason::

    t0 = time.perf_counter()   # jaxlint: disable=JAX005 — wall-clock measured backend

Report-only by design: no ``--fix``.  CLI::

    python -m repro.analysis.jaxlint src/repro    # exit 1 on findings
"""
from __future__ import annotations

import ast
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Union

JAX_RULES = {
    "JAX001": "mixed uint64/Python-int arithmetic (silent float64 "
              "promotion — the route() overflow class)",
    "JAX002": ".item()/float()/int()/bool() on a tracer inside a "
              "jit/scan body",
    "JAX003": "np.* call inside a jitted/scanned closure (constant-"
              "folded at trace time)",
    "JAX004": "unscoped jax_enable_x64 mutation",
    "JAX005": "nondeterminism (wall clock / unseeded random) in a "
              "planner/scheduler module",
    "JAX006": "mutable default argument (def f(x=[]) or dataclass "
              "field x: list = [])",
}

_SUPPRESS_RE = re.compile(r"#\s*jaxlint:\s*disable=([A-Z0-9,\s]+)")

# JAX005 only bites where determinism is load-bearing: the planning DP
# and the runtime scheduler/control modules the differential tests pin.
_JAX005_PATHS = re.compile(
    r"(^|/)(core/[^/]+\.py"
    r"|runtime/(migration|control|serving|simulator|scenarios|ft"
    r"|elastic|checkpoint)\.py)$")

_BIG_INT = 1 << 32

_TRACING_ARGS = {          # callee name -> positions holding traced fns
    "scan": (0,), "fori_loop": (2,), "while_loop": (0, 1),
    "cond": (1, 2), "jit": (0,), "pjit": (0,), "remat": (0,),
    "checkpoint": (0,),
}

_FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]


@dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    """x.y.z -> ["x", "y", "z"]; None if the root isn't a plain Name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


class _Aliases:
    """What local names the footgun modules/functions are bound to."""

    def __init__(self, tree: ast.AST):
        self.numpy: Set[str] = set()
        self.jnp: Set[str] = set()
        self.time_mod: Set[str] = set()
        self.random_mod: Set[str] = set()
        self.datetime_mod: Set[str] = set()
        self.uint64_names: Set[str] = set()      # from numpy import uint64
        self.time_funcs: Set[str] = set()        # from time import time, …
        self.random_funcs: Set[str] = set()
        self.jit_names: Set[str] = {"jit", "pjit"}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name.split(".")[0]
                    if a.name == "numpy":
                        self.numpy.add(name)
                    elif a.name == "jax.numpy":
                        self.jnp.add(a.asname or "jax")
                    elif a.name == "time":
                        self.time_mod.add(name)
                    elif a.name == "random":
                        self.random_mod.add(name)
                    elif a.name == "datetime":
                        self.datetime_mod.add(name)
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                for a in node.names:
                    name = a.asname or a.name
                    if mod == "numpy" and a.name == "uint64":
                        self.uint64_names.add(name)
                    elif mod == "time" and a.name in ("time",
                                                      "perf_counter",
                                                      "monotonic"):
                        self.time_funcs.add(name)
                    elif mod == "random":
                        self.random_funcs.add(name)
                    elif mod in ("jax", "jax.experimental.pjit") \
                            and a.name in ("jit", "pjit"):
                        self.jit_names.add(name)

    def is_uint64_call(self, node: ast.AST) -> bool:
        if not isinstance(node, ast.Call):
            return False
        chain = _attr_chain(node.func)
        if not chain:
            return False
        if len(chain) == 1:
            return chain[0] in self.uint64_names
        return chain[-1] == "uint64" and \
            chain[0] in (self.numpy | self.jnp)

    def is_jitish(self, node: ast.AST) -> bool:
        """Is this expression a jit transform (possibly partial-applied)?"""
        chain = _attr_chain(node)
        if chain and chain[-1] in self.jit_names:
            return True
        if isinstance(node, ast.Call):          # jit(...)(f), partial(jit…)
            if self.is_jitish(node.func):
                return True
            fchain = _attr_chain(node.func)
            if fchain and fchain[-1] == "partial" and node.args:
                return self.is_jitish(node.args[0])
        return False


def _collect_traced_roots(tree: ast.AST, al: _Aliases) -> Set[ast.AST]:
    """Functions whose bodies execute under jax tracing: jit-decorated
    defs, defs/lambdas passed to lax.scan / fori_loop / while_loop /
    cond / jit, and anything assigned through jit(f)."""
    roots: Set[ast.AST] = set()
    traced_names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(al.is_jitish(d) for d in node.decorator_list):
                roots.add(node)
        elif isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            positions = ()
            if chain and chain[-1] in _TRACING_ARGS:
                positions = _TRACING_ARGS[chain[-1]]
            elif al.is_jitish(node.func):
                positions = (0,)
            for p in positions:
                if p < len(node.args):
                    arg = node.args[p]
                    if isinstance(arg, ast.Lambda):
                        roots.add(arg)
                    elif isinstance(arg, ast.Name):
                        traced_names.add(arg.id)
    if traced_names:
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in traced_names:
                roots.add(node)
    return roots


class _Walker:
    def __init__(self, path: str, tree: ast.Module, apply_jax005: bool):
        self.path = path
        self.al = _Aliases(tree)
        self.traced_roots = _collect_traced_roots(tree, self.al)
        self.apply_jax005 = apply_jax005
        self.findings: List[LintFinding] = []
        self.parent: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node
        self.tree = tree

    def emit(self, node: ast.AST, rule: str, msg: str) -> None:
        self.findings.append(
            LintFinding(self.path, getattr(node, "lineno", 0), rule, msg))

    def _in_traced_scope(self, node: ast.AST) -> bool:
        cur = node
        while cur in self.parent:
            cur = self.parent[cur]
            if cur in self.traced_roots:
                return True
        return False

    def _inside_uint64_wrap(self, node: ast.AST) -> bool:
        cur = node
        while cur in self.parent:
            cur = self.parent[cur]
            if self.al.is_uint64_call(cur):
                return True
            if isinstance(cur, (ast.stmt, ast.Lambda)):
                break
        return False

    # -- rules --------------------------------------------------------------
    def _jax001(self, node: ast.BinOp) -> None:
        def big_int(n: ast.AST) -> bool:
            return isinstance(n, ast.Constant) and isinstance(n.value, int) \
                and not isinstance(n.value, bool) and abs(n.value) >= _BIG_INT

        def bare_int(n: ast.AST) -> bool:
            return isinstance(n, ast.Constant) and isinstance(n.value, int) \
                and not isinstance(n.value, bool)

        sides = (node.left, node.right)
        if any(big_int(s) for s in sides) \
                and not self._inside_uint64_wrap(node):
            self.emit(node, "JAX001",
                      "int literal ≥ 2^32 in arithmetic outside a "
                      "uint64(...) wrap — numpy promotes the mix to "
                      "float64 and corrupts the low bits")
        elif any(self.al.is_uint64_call(s) for s in sides) \
                and any(bare_int(s) for s in sides):
            self.emit(node, "JAX001",
                      "uint64(...) mixed with a bare Python int in one "
                      "binary op — wrap both operands")

    def _jax002_003(self, node: ast.Call) -> None:
        if not self._in_traced_scope(node):
            return
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item":
            self.emit(node, "JAX002",
                      ".item() inside a traced body concretizes the "
                      "tracer (ConcretizationTypeError at trace time)")
            return
        if isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int", "bool") \
                and node.args \
                and not all(isinstance(a, ast.Constant)
                            for a in node.args):
            self.emit(node, "JAX002",
                      f"{node.func.id}() on a traced value inside a "
                      f"jit/scan body")
            return
        chain = _attr_chain(node.func)
        if chain and len(chain) >= 2 and chain[0] in self.al.numpy:
            self.emit(node, "JAX003",
                      f"{'.'.join(chain)}(...) inside a traced body is "
                      f"constant-folded at trace time — use jnp")

    def _jax004(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if not chain or chain[-1] != "update" or "config" not in chain:
            return
        if node.args and isinstance(node.args[0], ast.Constant) \
                and node.args[0].value == "jax_enable_x64":
            self.emit(node, "JAX004",
                      'config.update("jax_enable_x64", ...) mutates '
                      "global precision for everything imported after "
                      "it — scope it or set it once at entry")

    def _jax005(self, node: ast.Call) -> None:
        if not self.apply_jax005:
            return
        chain = _attr_chain(node.func)
        if chain is None:
            return
        al = self.al
        if len(chain) == 2 and chain[0] in al.time_mod \
                and chain[1] in ("time", "perf_counter", "monotonic"):
            self.emit(node, "JAX005",
                      f"{'.'.join(chain)}() wall clock in a planner/"
                      f"scheduler module breaks reproducibility")
        elif len(chain) == 1 and chain[0] in (al.time_funcs
                                              | al.random_funcs):
            self.emit(node, "JAX005",
                      f"{chain[0]}() (wall clock / unseeded random) in "
                      f"a planner/scheduler module")
        elif len(chain) >= 2 and chain[0] in al.random_mod:
            self.emit(node, "JAX005",
                      f"{'.'.join(chain)}() unseeded stdlib random in a "
                      f"planner/scheduler module")
        elif len(chain) >= 3 and chain[0] in al.numpy \
                and chain[1] == "random":
            if chain[2] == "default_rng" and node.args:
                return                     # seeded generator: fine
            self.emit(node, "JAX005",
                      f"{'.'.join(chain)}() global/unseeded np.random "
                      f"in a planner/scheduler module — use "
                      f"default_rng(seed)")
        elif len(chain) >= 2 and chain[0] in al.datetime_mod \
                and chain[-1] in ("now", "utcnow", "today"):
            self.emit(node, "JAX005",
                      f"{'.'.join(chain)}() wall clock in a planner/"
                      f"scheduler module")

    def _jax006_def(self, node: _FuncNode) -> None:
        for d in list(node.args.defaults) + \
                [k for k in node.args.kw_defaults if k is not None]:
            if self._mutable_literal(d):
                name = getattr(node, "name", "<lambda>")
                self.emit(d, "JAX006",
                          f"mutable default argument in {name}() — one "
                          f"shared object across all calls; use None or "
                          f"field(default_factory=...)")

    def _jax006_class(self, node: ast.ClassDef) -> None:
        for stmt in node.body:
            val = None
            if isinstance(stmt, ast.AnnAssign):
                val = stmt.value
            elif isinstance(stmt, ast.Assign):
                val = stmt.value
            if val is not None and self._mutable_literal(val):
                self.emit(val, "JAX006",
                          f"mutable class-level default in {node.name} — "
                          f"shared across instances; use "
                          f"field(default_factory=...)")

    @staticmethod
    def _mutable_literal(node: ast.AST) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set)):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("list", "dict", "set") \
                and not node.args and not node.keywords:
            return True
        return False

    def run(self) -> List[LintFinding]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.BinOp):
                self._jax001(node)
            elif isinstance(node, ast.Call):
                self._jax002_003(node)
                self._jax004(node)
                self._jax005(node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                self._jax006_def(node)
            elif isinstance(node, ast.ClassDef):
                self._jax006_class(node)
        return self.findings


def _suppressed_rules(lines: Sequence[str], lineno: int) -> Set[str]:
    """Rules disabled for 1-indexed ``lineno`` — by a trailing comment on
    the line itself or a standalone comment on the line above."""
    out: Set[str] = set()
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            text = lines[ln - 1]
            if ln != lineno and not text.lstrip().startswith("#"):
                continue               # line above counts only standalone
            m = _SUPPRESS_RE.search(text)
            if m:
                out |= {r.strip() for r in m.group(1).split(",")
                        if r.strip()}
    return out


def lint_file(path, text: Optional[str] = None) -> List[LintFinding]:
    """Lint one file; returns unsuppressed findings."""
    p = str(path)
    if text is None:
        text = Path(p).read_text()
    try:
        tree = ast.parse(text, filename=p)
    except SyntaxError as e:
        return [LintFinding(p, e.lineno or 0, "JAX000",
                            f"syntax error: {e.msg}")]
    posix = Path(p).as_posix()
    walker = _Walker(p, tree, apply_jax005=bool(_JAX005_PATHS.search(posix)))
    findings = walker.run()
    lines = text.splitlines()
    return [f for f in findings
            if f.rule not in _suppressed_rules(lines, f.line)]


def lint_paths(paths: Iterable) -> List[LintFinding]:
    """Lint files and directories (recursively, ``*.py``)."""
    out: List[LintFinding] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            out.extend(lint_file(f))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        argv = ["src/repro"]
    findings = lint_paths(argv)
    for f in findings:
        print(f)
    if findings:
        print(f"jaxlint: {len(findings)} finding(s) in {len(argv)} "
              f"path(s)", file=sys.stderr)
        return 1
    print(f"jaxlint: clean ({', '.join(map(str, argv))})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
