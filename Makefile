# Repo entrypoints.  `make test` is the ROADMAP.md tier-1 command.
.PHONY: test test-fast lint bench bench-fig12 fig13 check-bench quickstart

test:
	scripts/ci.sh

test-fast:
	scripts/ci.sh fast

lint:
	scripts/ci.sh lint

bench:
	PYTHONPATH=src python -m benchmarks.run

bench-fig12:
	PYTHONPATH=src python -m benchmarks.fig12_fluid_vs_progressive

fig13:
	PYTHONPATH=src python -m benchmarks.fig13_controller

check-bench:
	python scripts/check_bench.py

quickstart:
	PYTHONPATH=src python examples/quickstart.py
